//! Log-bucketed latency histograms and live counters for the serve
//! daemon — continuous observability instead of end-of-run stats.
//!
//! A [`Histogram`] is a fixed array of power-of-two latency buckets
//! behind relaxed atomics: recording a sample is one `fetch_add`, no
//! allocation, no lock — cheap enough to leave on for every request the
//! daemon serves. Bucket `k` holds durations in `[2^(k-1), 2^k)`
//! nanoseconds (bucket 0 holds 0 ns), so quantile queries return a
//! bucket *bound* with a guaranteed factor-2 resolution: the true
//! nearest-rank quantile always lies inside the reported bucket. That is
//! the contract `serve_load` asserts ("histogram and sort-based
//! quantiles agree within one bucket") and what lets two histograms
//! merge associatively — per-bucket counter addition loses nothing the
//! buckets had not already quantized away.
//!
//! [`ServeMetrics`] packages one histogram per serve op plus
//! cache-outcome counters (hit / miss / error, one relaxed atomic each —
//! the warmed hit path stays allocation-free, proven by
//! `rust/tests/obs_alloc.rs`) and renders both a JSON object for the
//! serve `metrics` op and a Prometheus-style text exposition.

use crate::util::json::Json;
use std::sync::atomic::{AtomicU64, Ordering};

/// Number of power-of-two buckets: bucket 47 tops out at 2^47 ns ≈ 39 h,
/// far beyond any request latency; larger samples clamp into it.
pub const BUCKETS: usize = 48;

/// A log-bucketed histogram of nanosecond durations. All methods take
/// `&self`; concurrent recording is lock-free and allocation-free.
#[derive(Debug)]
pub struct Histogram {
    counts: [AtomicU64; BUCKETS],
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

/// Bucket index for a duration: 0 for 0 ns, else `⌈log2(ns+1)⌉` clamped
/// to the last bucket — so bucket `k ≥ 1` covers `[2^(k-1), 2^k)`.
pub fn bucket_of(ns: u64) -> usize {
    (64 - ns.leading_zeros() as usize).min(BUCKETS - 1)
}

/// Inclusive value range `[lo, hi]` a bucket covers (the last bucket's
/// upper bound is saturated).
pub fn bucket_bounds(idx: usize) -> (u64, u64) {
    match idx {
        0 => (0, 0),
        k if k < BUCKETS - 1 => (1u64 << (k - 1), (1u64 << k) - 1),
        k => (1u64 << (k - 1), u64::MAX),
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram { counts: std::array::from_fn(|_| AtomicU64::new(0)) }
    }

    /// Record one duration. One relaxed `fetch_add`, no allocation.
    #[inline]
    pub fn record_ns(&self, ns: u64) {
        self.counts[bucket_of(ns)].fetch_add(1, Ordering::Relaxed);
    }

    /// Total samples recorded.
    pub fn count(&self) -> u64 {
        self.counts.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }

    /// Add every bucket of `other` into `self`. Per-bucket counter
    /// addition is associative and commutative, so merging partial
    /// histograms in any grouping yields the same result — the property
    /// `rust/tests/analyze.rs` checks.
    pub fn merge_from(&self, other: &Histogram) {
        for (dst, src) in self.counts.iter().zip(&other.counts) {
            dst.fetch_add(src.load(Ordering::Relaxed), Ordering::Relaxed);
        }
    }

    /// Raw bucket counts (a consistent-enough snapshot for reporting).
    pub fn snapshot(&self) -> [u64; BUCKETS] {
        std::array::from_fn(|i| self.counts[i].load(Ordering::Relaxed))
    }

    /// Index of the bucket containing the nearest-rank `q`-quantile
    /// (`0.0 ..= 1.0`), or `None` if no samples were recorded.
    pub fn quantile_bucket(&self, q: f64) -> Option<usize> {
        let snap = self.snapshot();
        let total: u64 = snap.iter().sum();
        if total == 0 {
            return None;
        }
        // Nearest-rank over the sorted multiset the buckets quantize:
        // the same `((n-1) * q).round()` rule the old sort-based path
        // used, so the two can only disagree by bucket resolution.
        let rank = ((total - 1) as f64 * q.clamp(0.0, 1.0)).round() as u64;
        let mut seen = 0u64;
        for (i, &c) in snap.iter().enumerate() {
            seen += c;
            if seen > rank {
                return Some(i);
            }
        }
        Some(BUCKETS - 1)
    }

    /// Upper bound (ns) of the bucket holding the `q`-quantile — the
    /// value the daemon reports. 0 when empty.
    pub fn quantile_upper_ns(&self, q: f64) -> u64 {
        self.quantile_bucket(q).map(|b| bucket_bounds(b).1).unwrap_or(0)
    }

    /// The reported quantile in microseconds (upper bucket bound).
    pub fn quantile_us(&self, q: f64) -> f64 {
        self.quantile_upper_ns(q) as f64 / 1_000.0
    }

    /// JSON summary: sample count plus the standard latency quantiles.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::Num(self.count() as f64)),
            ("p50_us", Json::Num(self.quantile_us(0.50))),
            ("p99_us", Json::Num(self.quantile_us(0.99))),
            ("p999_us", Json::Num(self.quantile_us(0.999))),
        ])
    }
}

/// The serve ops that get a latency histogram each. Closed set — the
/// registry is a fixed array, so lookup is a handful of pointer
/// comparisons and never allocates.
pub const SERVE_OPS: [&str; 7] =
    ["plan", "batch", "invalidate", "stats", "metrics", "ping", "shutdown"];

/// Live metrics behind the serve daemon: per-op latency histograms plus
/// cache-outcome counters. Every update is relaxed-atomic; the warmed
/// plan hit costs exactly one counter increment beyond the probe itself.
#[derive(Debug, Default)]
pub struct ServeMetrics {
    ops: [Histogram; SERVE_OPS.len()],
    pub cache_hits: AtomicU64,
    pub cache_misses: AtomicU64,
    pub errors: AtomicU64,
}

impl ServeMetrics {
    pub fn new() -> ServeMetrics {
        ServeMetrics::default()
    }

    /// The histogram for a named op (unknown names fold into the last
    /// slot rather than panicking a live daemon).
    pub fn op(&self, name: &str) -> &Histogram {
        let idx = SERVE_OPS.iter().position(|o| *o == name).unwrap_or(SERVE_OPS.len() - 1);
        &self.ops[idx]
    }

    /// Record one handled request: latency into the op's histogram.
    #[inline]
    pub fn record_op_ns(&self, name: &str, ns: u64) {
        self.op(name).record_ns(ns);
    }

    /// What the serve `metrics` op returns: per-op quantiles, outcome
    /// counters, and the Prometheus-style exposition text.
    pub fn to_json(&self) -> Json {
        let ops = Json::Obj(
            SERVE_OPS
                .iter()
                .zip(&self.ops)
                .filter(|(_, h)| h.count() > 0)
                .map(|(name, h)| (name.to_string(), h.to_json()))
                .collect(),
        );
        Json::obj(vec![
            ("ops", ops),
            (
                "cache",
                Json::obj(vec![
                    ("hit", Json::Num(self.cache_hits.load(Ordering::Relaxed) as f64)),
                    ("miss", Json::Num(self.cache_misses.load(Ordering::Relaxed) as f64)),
                    ("error", Json::Num(self.errors.load(Ordering::Relaxed) as f64)),
                ]),
            ),
            ("exposition", Json::Str(self.prometheus())),
        ])
    }

    /// Prometheus text exposition: request counts and latency quantiles
    /// per op, cumulative bucket counts for the `plan` op, and the
    /// cache-outcome counters.
    pub fn prometheus(&self) -> String {
        use std::fmt::Write;
        let mut out = String::new();
        out.push_str("# TYPE mapple_serve_requests_total counter\n");
        for (name, h) in SERVE_OPS.iter().zip(&self.ops) {
            let n = h.count();
            if n > 0 {
                let _ = writeln!(out, "mapple_serve_requests_total{{op=\"{name}\"}} {n}");
            }
        }
        out.push_str("# TYPE mapple_serve_latency_seconds summary\n");
        for (name, h) in SERVE_OPS.iter().zip(&self.ops) {
            if h.count() == 0 {
                continue;
            }
            for (label, q) in [("0.5", 0.50), ("0.99", 0.99), ("0.999", 0.999)] {
                let secs = h.quantile_upper_ns(q) as f64 / 1e9;
                let _ = writeln!(
                    out,
                    "mapple_serve_latency_seconds{{op=\"{name}\",quantile=\"{label}\"}} {secs:e}"
                );
            }
        }
        out.push_str("# TYPE mapple_serve_latency_bucket histogram\n");
        let mut cum = 0u64;
        for (i, c) in self.op("plan").snapshot().iter().enumerate() {
            cum += c;
            if *c > 0 {
                let le = bucket_bounds(i).1 as f64 / 1e9;
                let _ = writeln!(
                    out,
                    "mapple_serve_latency_bucket{{op=\"plan\",le=\"{le:e}\"}} {cum}"
                );
            }
        }
        out.push_str("# TYPE mapple_serve_cache_outcomes_total counter\n");
        for (label, n) in [
            ("hit", self.cache_hits.load(Ordering::Relaxed)),
            ("miss", self.cache_misses.load(Ordering::Relaxed)),
            ("error", self.errors.load(Ordering::Relaxed)),
        ] {
            let _ = writeln!(out, "mapple_serve_cache_outcomes_total{{outcome=\"{label}\"}} {n}");
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_cover_the_line_without_gaps() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 1);
        assert_eq!(bucket_of(2), 2);
        assert_eq!(bucket_of(3), 2);
        assert_eq!(bucket_of(4), 3);
        assert_eq!(bucket_of(u64::MAX), BUCKETS - 1);
        for k in 0..BUCKETS - 1 {
            let (lo, hi) = bucket_bounds(k);
            assert_eq!(bucket_of(lo), k, "lower bound of bucket {k}");
            assert_eq!(bucket_of(hi), k, "upper bound of bucket {k}");
            assert_eq!(bucket_bounds(k + 1).0, hi.wrapping_add(1).max(1));
        }
    }

    #[test]
    fn quantiles_track_nearest_rank_within_one_bucket() {
        let h = Histogram::new();
        let mut samples: Vec<u64> = (0..1000).map(|i| (i * i) % 50_000 + 1).collect();
        for &s in &samples {
            h.record_ns(s);
        }
        samples.sort_unstable();
        for q in [0.5, 0.9, 0.99, 0.999] {
            let exact = samples[((samples.len() - 1) as f64 * q).round() as usize];
            let b = h.quantile_bucket(q).unwrap();
            let diff = (bucket_of(exact) as i64 - b as i64).abs();
            assert!(diff <= 1, "q={q}: exact {exact} in bucket {}, hist {b}", bucket_of(exact));
            let (_, hi) = bucket_bounds(b);
            assert!(hi >= exact / 2, "upper bound {hi} vs exact {exact}");
        }
    }

    #[test]
    fn merge_is_associative() {
        let mk = |vals: &[u64]| {
            let h = Histogram::new();
            for &v in vals {
                h.record_ns(v);
            }
            h
        };
        let (a, b, c) = (mk(&[1, 5, 9000]), mk(&[2, 2, 70]), mk(&[u64::MAX, 0]));
        let left = Histogram::new();
        left.merge_from(&a);
        left.merge_from(&b); // (a + b)
        let right = mk(&[]);
        right.merge_from(&b);
        right.merge_from(&c); // (b + c)
        let lhs = Histogram::new();
        lhs.merge_from(&left);
        lhs.merge_from(&c); // (a + b) + c
        let rhs = Histogram::new();
        rhs.merge_from(&a);
        rhs.merge_from(&right); // a + (b + c)
        assert_eq!(lhs.snapshot(), rhs.snapshot());
        assert_eq!(lhs.count(), 7);
    }

    #[test]
    fn serve_metrics_exposition_lists_recorded_ops() {
        let m = ServeMetrics::new();
        m.record_op_ns("plan", 1500);
        m.record_op_ns("plan", 3000);
        m.record_op_ns("ping", 100);
        m.cache_hits.fetch_add(2, Ordering::Relaxed);
        let text = m.prometheus();
        assert!(text.contains("mapple_serve_requests_total{op=\"plan\"} 2"), "{text}");
        assert!(text.contains("mapple_serve_requests_total{op=\"ping\"} 1"), "{text}");
        assert!(text.contains("cache_outcomes_total{outcome=\"hit\"} 2"), "{text}");
        assert!(!text.contains("op=\"batch\""), "empty ops stay out: {text}");
        let j = m.to_json();
        assert!(j.get("ops").and_then(|o| o.get("plan")).is_some());
        assert_eq!(
            j.get("cache").and_then(|c| c.get("hit")).and_then(|h| h.as_f64()),
            Some(2.0)
        );
    }
}
