//! Per-task-family cost breakdowns — the same schema from the simulator
//! and the executor, so modelled and measured costs diff row-for-row.
//!
//! A breakdown is a map from **task family** (the launch name — both
//! producers derive rows from the same launch list, so the row keys are
//! identical by construction) to one [`FamilyRow`]:
//!
//! ```json
//! {
//!   "source": "sim" | "exec",
//!   "dropped_events": 0,
//!   "families": {
//!     "<launch name>": {
//!       "tasks": 16,
//!       "compute_ns": 1234.5,
//!       "wait_ns": 67.8,
//!       "intra_bytes": 4096,
//!       "inter_bytes": 8192,
//!       "edges": { "<region name>": { "intra": 4096, "inter": 8192 } }
//!     }
//!   }
//! }
//! ```
//!
//! Semantics per source:
//! - **sim** — `compute_ns` is the modelled kernel time on the paper
//!   testbed; `wait_ns` is time a ready task spent queued behind its
//!   processor; bytes are the modelled gather traffic, attributed to
//!   the *consuming* family per region.
//! - **exec** — `compute_ns`/`wait_ns` are measured on this host from
//!   the trace's kernel/wait spans; bytes are the plan-time totals
//!   (schedule-independent, attributed to the consuming family per
//!   region — the identical attribution rule, so the byte columns are
//!   directly comparable while the time columns are model vs
//!   measurement).
//!
//! `BTreeMap` keys make the JSON stable: same run, same bytes out.

use crate::util::json::Json;
use std::collections::BTreeMap;

/// Bytes pulled over one region edge into a family's tasks.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct EdgeBytes {
    /// On-node (NVLink-class) pulls.
    pub intra: u64,
    /// Cross-node transfers.
    pub inter: u64,
}

/// One task family's costs.
#[derive(Clone, Debug, Default)]
pub struct FamilyRow {
    pub tasks: u64,
    pub compute_ns: f64,
    pub wait_ns: f64,
    pub intra_bytes: u64,
    pub inter_bytes: u64,
    /// Region name → bytes moved to feed this family's reads.
    pub edges: BTreeMap<String, EdgeBytes>,
}

impl FamilyRow {
    /// Record `bytes` pulled over `region` into this family's tasks,
    /// keeping the per-edge map and the row totals consistent.
    pub fn add_edge(&mut self, region: &str, bytes: u64, intra: bool) {
        let e = self.edges.entry(region.to_string()).or_default();
        if intra {
            e.intra += bytes;
            self.intra_bytes += bytes;
        } else {
            e.inter += bytes;
            self.inter_bytes += bytes;
        }
    }
}

/// The full per-family breakdown from one run (sim or exec).
#[derive(Clone, Debug)]
pub struct Breakdown {
    /// `"sim"` or `"exec"`.
    pub source: &'static str,
    pub rows: BTreeMap<String, FamilyRow>,
    /// Trace events lost to ring overflow while collecting (exec only;
    /// always 0 for sim). Non-zero means measured times undercount.
    pub dropped_events: u64,
}

impl Breakdown {
    pub fn new(source: &'static str) -> Breakdown {
        Breakdown { source, rows: BTreeMap::new(), dropped_events: 0 }
    }

    /// The row for a family, created empty on first touch.
    pub fn row(&mut self, family: &str) -> &mut FamilyRow {
        self.rows.entry(family.to_string()).or_default()
    }

    /// Row keys in stable (sorted) order — what the schema test diffs.
    pub fn row_keys(&self) -> Vec<&str> {
        self.rows.keys().map(|k| k.as_str()).collect()
    }

    pub fn to_json(&self) -> Json {
        let families = Json::Obj(
            self.rows
                .iter()
                .map(|(fam, row)| {
                    let edges = Json::Obj(
                        row.edges
                            .iter()
                            .map(|(region, e)| {
                                let obj = Json::obj(vec![
                                    ("intra", Json::Num(e.intra as f64)),
                                    ("inter", Json::Num(e.inter as f64)),
                                ]);
                                (region.clone(), obj)
                            })
                            .collect(),
                    );
                    let obj = Json::obj(vec![
                        ("tasks", Json::Num(row.tasks as f64)),
                        ("compute_ns", Json::Num(row.compute_ns)),
                        ("wait_ns", Json::Num(row.wait_ns)),
                        ("intra_bytes", Json::Num(row.intra_bytes as f64)),
                        ("inter_bytes", Json::Num(row.inter_bytes as f64)),
                        ("edges", edges),
                    ]);
                    (fam.clone(), obj)
                })
                .collect(),
        );
        Json::obj(vec![
            ("source", Json::Str(self.source.to_string())),
            ("dropped_events", Json::Num(self.dropped_events as f64)),
            ("families", families),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_rows_are_stable_and_schema_identical_across_sources() {
        let mut sim = Breakdown::new("sim");
        let mut exec = Breakdown::new("exec");
        for b in [&mut sim, &mut exec] {
            let r = b.row("matmul");
            r.tasks = 4;
            r.compute_ns = 10.0;
            r.edges.insert("A".to_string(), EdgeBytes { intra: 16, inter: 32 });
            r.intra_bytes = 16;
            r.inter_bytes = 32;
            b.row("init");
        }
        assert_eq!(sim.row_keys(), exec.row_keys());
        let (sj, ej) = (sim.to_json(), exec.to_json());
        // Identical schema: same top-level keys, same per-row keys.
        let keys = |j: &Json| match j {
            Json::Obj(m) => m.keys().cloned().collect::<Vec<_>>(),
            other => panic!("expected object, got {other:?}"),
        };
        assert_eq!(keys(&sj), keys(&ej));
        let row = |j: &Json, fam: &str| keys(j.get("families").unwrap().get(fam).unwrap());
        assert_eq!(row(&sj, "matmul"), row(&ej, "matmul"));
        assert_eq!(row(&sj, "init"), row(&ej, "init"));
        assert_eq!(sj.get("source").and_then(|s| s.as_str()), Some("sim"));
    }
}
