//! The mapping advisor: turn critical-path + breakdown structure into a
//! ranked, machine-readable advice report (`mapple analyze`).
//!
//! Advice is derived **exclusively from the simulator's modelled run**
//! (critical path, breakdown byte volumes, timeline queue times) — all
//! pure functions of the mapping and machine shape — so the report is
//! bitwise deterministic: same app, mapper, and shape in, same advice
//! out, regardless of exec worker counts, tracing capacity, or host
//! noise. The measured exec critical path rides alongside in the
//! `mapple analyze` output for humans (and outer optimizers) to judge
//! how far the model is from the measurement; `mapple tune --validate`
//! quantifies that trust with a rank-correlation report.
//!
//! **Advice schema** (`mapple.advice/v1`): every finding carries the
//! same keys — `rank`, `kind`, `title`, `severity_ns`, `share`,
//! `family`, `region`, `lane`, `bytes` (null where not applicable) and a
//! `suggestions` list of `{knob, action}` pairs naming which
//! transform/decompose knob in the typed-op space plausibly addresses
//! the finding. Kinds:
//! - `critical_path_family` — a family's total time on the critical
//!   path, with its dominant blame category steering the suggestion;
//! - `inter_edge` — a top-k inter-node transfer edge (family ← region)
//!   by byte volume, `severity_ns` estimated as bytes / IB bandwidth;
//! - `wait_hotspot` — a processor lane whose modelled queue time (tasks
//!   ready but waiting for the lane) is a large makespan fraction.
//!
//! Findings are ranked by `severity_ns` descending with a stable
//! `(kind, title)` tie-break.

use crate::machine::topology::MachineDesc;
use crate::obs::breakdown::Breakdown;
use crate::obs::critpath::CritPath;
use crate::sim::SimTimeline;
use crate::util::json::Json;

/// Schema identifier stamped into every advice report.
pub const ADVICE_SCHEMA: &str = "mapple.advice/v1";

/// One `{knob, action}` suggestion in the typed-op space.
#[derive(Clone, Debug)]
pub struct Suggestion {
    /// Which knob family: `transform`, `decompose`, `memory`,
    /// `backpressure`, or `gc`.
    pub knob: &'static str,
    pub action: String,
}

/// One ranked finding.
#[derive(Clone, Debug)]
pub struct Finding {
    pub kind: &'static str,
    pub title: String,
    /// Modelled nanoseconds at stake (for `inter_edge`: bytes / IB bw).
    pub severity_ns: f64,
    /// `severity_ns` as a fraction of the modelled makespan.
    pub share: f64,
    pub family: Option<String>,
    pub region: Option<String>,
    pub lane: Option<String>,
    pub bytes: Option<u64>,
    pub suggestions: Vec<Suggestion>,
}

/// The full advice report for one (app, mapper, shape).
#[derive(Clone, Debug)]
pub struct Advice {
    pub app: String,
    pub mapper: String,
    pub nodes: usize,
    pub gpus_per_node: usize,
    /// Modelled makespan the shares are fractions of.
    pub makespan_seconds: f64,
    /// Ranked findings, most severe first.
    pub findings: Vec<Finding>,
}

impl Advice {
    pub fn to_json(&self) -> Json {
        let findings = Json::Arr(
            self.findings
                .iter()
                .enumerate()
                .map(|(i, f)| {
                    let opt_str =
                        |v: &Option<String>| v.clone().map(Json::Str).unwrap_or(Json::Null);
                    let suggestions = Json::Arr(
                        f.suggestions
                            .iter()
                            .map(|s| {
                                Json::obj(vec![
                                    ("knob", Json::Str(s.knob.to_string())),
                                    ("action", Json::Str(s.action.clone())),
                                ])
                            })
                            .collect(),
                    );
                    Json::obj(vec![
                        ("rank", Json::Num((i + 1) as f64)),
                        ("kind", Json::Str(f.kind.to_string())),
                        ("title", Json::Str(f.title.clone())),
                        ("severity_ns", Json::Num(f.severity_ns)),
                        ("share", Json::Num(f.share)),
                        ("family", opt_str(&f.family)),
                        ("region", opt_str(&f.region)),
                        ("lane", opt_str(&f.lane)),
                        (
                            "bytes",
                            f.bytes.map(|b| Json::Num(b as f64)).unwrap_or(Json::Null),
                        ),
                        ("suggestions", suggestions),
                    ])
                })
                .collect(),
        );
        Json::obj(vec![
            ("schema", Json::Str(ADVICE_SCHEMA.to_string())),
            ("app", Json::Str(self.app.clone())),
            ("mapper", Json::Str(self.mapper.clone())),
            ("nodes", Json::Num(self.nodes as f64)),
            ("gpus_per_node", Json::Num(self.gpus_per_node as f64)),
            ("makespan_seconds", Json::Num(self.makespan_seconds)),
            ("findings", findings),
        ])
    }
}

fn family_findings(cp: &CritPath, makespan_ns: f64, out: &mut Vec<Finding>) {
    let mut fams: Vec<(&String, f64)> =
        cp.blame.iter().map(|(f, r)| (f, r.total_ns())).filter(|(_, t)| *t > 0.0).collect();
    fams.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then_with(|| a.0.cmp(b.0)));
    for (fam, total) in fams.into_iter().take(3) {
        let row = &cp.blame[fam];
        let cats = [
            ("compute", row.compute_ns),
            ("wait", row.wait_ns),
            ("intra-transfer", row.intra_transfer_ns),
            ("inter-transfer", row.inter_transfer_ns),
        ];
        let (dom, _) =
            cats.iter().copied().max_by(|a, b| a.1.partial_cmp(&b.1).unwrap()).unwrap();
        let suggestions = match dom {
            "inter-transfer" => vec![
                Suggestion {
                    knob: "decompose",
                    action: format!(
                        "re-run decompose for `{fam}` with the communication-volume objective \
                         so producer and consumer tiles share a node"
                    ),
                },
                Suggestion {
                    knob: "transform",
                    action: format!(
                        "add chain ops (swap/rotate) to `{fam}`'s machine view to co-locate it \
                         with the partition that feeds it"
                    ),
                },
            ],
            "intra-transfer" => vec![
                Suggestion {
                    knob: "memory",
                    action: format!(
                        "map `{fam}`'s read arguments to ZCMEM so repeated on-node pulls become \
                         zero-copy"
                    ),
                },
                Suggestion {
                    knob: "gc",
                    action: format!(
                        "drop any `gc` directive on `{fam}`'s inputs so re-read tiles stay \
                         resident"
                    ),
                },
            ],
            "wait" => vec![
                Suggestion {
                    knob: "backpressure",
                    action: format!(
                        "raise or remove `backpressure` on `{fam}` so independent points overlap"
                    ),
                },
                Suggestion {
                    knob: "transform",
                    action: format!(
                        "split `{fam}` across more lanes (transform split/swap) to drain its \
                         queue"
                    ),
                },
            ],
            _ => vec![Suggestion {
                knob: "decompose",
                action: format!(
                    "`{fam}` is compute-bound on the path — widen its processor grid \
                     (decompose over more GPUs) or accept: transfers are not the bottleneck"
                ),
            }],
        };
        out.push(Finding {
            kind: "critical_path_family",
            title: format!("`{fam}` holds {:.1}% of the critical path ({dom}-dominated)",
                100.0 * total / makespan_ns.max(1.0)),
            severity_ns: total,
            share: total / makespan_ns.max(1.0),
            family: Some(fam.clone()),
            region: None,
            lane: None,
            bytes: None,
            suggestions,
        });
    }
}

fn edge_findings(bd: &Breakdown, desc: &MachineDesc, makespan_ns: f64, out: &mut Vec<Finding>) {
    let mut edges: Vec<(&String, &String, u64)> = Vec::new();
    for (fam, row) in &bd.rows {
        for (region, e) in &row.edges {
            if e.inter > 0 {
                edges.push((fam, region, e.inter));
            }
        }
    }
    edges.sort_by(|a, b| b.2.cmp(&a.2).then_with(|| (a.0, a.1).cmp(&(b.0, b.1))));
    for (fam, region, bytes) in edges.into_iter().take(5) {
        let est_ns = bytes as f64 / desc.ib_bw * 1e9;
        out.push(Finding {
            kind: "inter_edge",
            title: format!("`{fam}` pulls {bytes} bytes of `{region}` across nodes"),
            severity_ns: est_ns,
            share: est_ns / makespan_ns.max(1.0),
            family: Some(fam.clone()),
            region: Some(region.clone()),
            lane: None,
            bytes: Some(bytes),
            suggestions: vec![
                Suggestion {
                    knob: "decompose",
                    action: format!(
                        "decompose `{fam}` so its tiles of `{region}` land on the writer's node \
                         (communication-volume objective)"
                    ),
                },
                Suggestion {
                    knob: "transform",
                    action: format!(
                        "align `{fam}`'s index space with `{region}`'s partition via chain \
                         swap/rotate before the processor view"
                    ),
                },
            ],
        });
    }
}

fn hotspot_findings(tl: &SimTimeline, makespan_ns: f64, out: &mut Vec<Finding>) {
    // Modelled queue time per processor: task was data-ready but the
    // lane was busy. BTreeMap keys make iteration (and ranking ties)
    // deterministic.
    let mut queue: std::collections::BTreeMap<String, f64> = std::collections::BTreeMap::new();
    for t in &tl.tasks {
        let q = t.start - t.data_ready.max(t.dep_ready);
        if q > 0.0 {
            *queue.entry(t.proc.to_string()).or_default() += q * 1e9;
        }
    }
    let mut lanes: Vec<(String, f64)> = queue.into_iter().collect();
    lanes.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then_with(|| a.0.cmp(&b.0)));
    for (lane, ns) in lanes.into_iter().take(3) {
        if ns / makespan_ns.max(1.0) < 0.01 {
            continue; // below 1% of the makespan it is not a hotspot
        }
        out.push(Finding {
            kind: "wait_hotspot",
            title: format!("lane {lane} queues ready tasks for {:.0} µs", ns / 1e3),
            severity_ns: ns,
            share: ns / makespan_ns.max(1.0),
            family: None,
            region: None,
            lane: Some(lane.clone()),
            bytes: None,
            suggestions: vec![
                Suggestion {
                    knob: "transform",
                    action: format!(
                        "rebalance the machine view (swap/rotate/split) so fewer point tasks \
                         serialize on {lane}"
                    ),
                },
                Suggestion {
                    knob: "backpressure",
                    action: "if the queue is intentional (memory pressure), keep it; otherwise \
                             drop the backpressure window"
                        .to_string(),
                },
            ],
        });
    }
}

/// Build the ranked advice report from the modelled artifacts. Pure and
/// deterministic — see the module docs for why exec measurements are
/// deliberately not consulted.
pub fn advise(
    app: &str,
    mapper: &str,
    desc: &MachineDesc,
    sim_cp: &CritPath,
    sim_bd: &Breakdown,
    tl: &SimTimeline,
) -> Advice {
    let makespan_ns = sim_cp.length_seconds * 1e9;
    let mut findings = Vec::new();
    family_findings(sim_cp, makespan_ns, &mut findings);
    edge_findings(sim_bd, desc, makespan_ns, &mut findings);
    hotspot_findings(tl, makespan_ns, &mut findings);
    findings.sort_by(|a, b| {
        b.severity_ns
            .partial_cmp(&a.severity_ns)
            .unwrap()
            .then_with(|| (a.kind, &a.title).cmp(&(b.kind, &b.title)))
    });
    Advice {
        app: app.to_string(),
        mapper: mapper.to_string(),
        nodes: desc.nodes,
        gpus_per_node: desc.gpus_per_node,
        makespan_seconds: sim_cp.length_seconds,
        findings,
    }
}
