//! The autotuner's candidate genome: a declarative [`TuneSpec`] in the
//! typed-op space that compiles to a [`MapperSpec`] through the
//! `mapple::build` seam and pretty-prints to `.mpl` source.
//!
//! A genome is a *mutation of the seed mapper*: the seed keeps the app's
//! baseline mapping functions (`mappers/<app>.mpl`, reconstructed by
//! `apps::builder_mappers::install_mapping`) with no policy directives.
//! Mutations move through exactly the knobs the paper exposes:
//!
//! * the mapping function itself ([`MapFn`]: hierarchical decompose vs
//!   linearized block vs round-robin, over a `split`/`merge`/`swap`/
//!   `slice` transform chain),
//! * the decompose communication objective ([`Objective`]),
//! * per-argument memory placement (`Region` → [`MemKind`]),
//! * processor-kind selection (`TaskMap` → [`ProcKind`]),
//! * eager collection (`GarbageCollect`) and in-flight limits
//!   (`Backpressure`).

use crate::apps::builder_mappers;
use crate::decompose::Objective;
use crate::machine::space::ProcSpace;
use crate::machine::topology::{MachineDesc, MemKind, ProcKind};
use crate::mapple::build::{MachineView, MapperBuilder, VExpr};
use crate::mapple::program::MapperSpec;
use std::collections::{BTreeMap, BTreeSet};
use std::fmt::Write as _;

/// One Fig 6 machine-view transform in a candidate's chain.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChainOp {
    Split { dim: usize, factor: i64 },
    Merge { p: usize, q: usize },
    Swap { p: usize, q: usize },
    Slice { dim: usize, lo: i64, hi: i64 },
}

impl ChainOp {
    /// Apply to an eagerly transformed space (validity checking).
    pub fn apply_space(&self, s: &ProcSpace) -> Result<ProcSpace, String> {
        match *self {
            ChainOp::Split { dim, factor } => s.split(dim, factor),
            ChainOp::Merge { p, q } => s.merge(p, q),
            ChainOp::Swap { p, q } => s.swap(p, q),
            ChainOp::Slice { dim, lo, hi } => s.slice(dim, lo, hi),
        }
    }

    /// Apply to a deferred builder view (spec construction).
    fn apply_view(&self, v: &MachineView) -> MachineView {
        match *self {
            ChainOp::Split { dim, factor } => v.split(dim, factor),
            ChainOp::Merge { p, q } => v.merge(p, q),
            ChainOp::Swap { p, q } => v.swap(p, q),
            ChainOp::Slice { dim, lo, hi } => v.slice(dim, lo, hi),
        }
    }

    /// Surface-syntax rendering (`.split(0, 2)` …).
    fn mpl(&self) -> String {
        match *self {
            ChainOp::Split { dim, factor } => format!(".split({dim}, {factor})"),
            ChainOp::Merge { p, q } => format!(".merge({p}, {q})"),
            ChainOp::Swap { p, q } => format!(".swap({p}, {q})"),
            ChainOp::Slice { dim, lo, hi } => format!(".slice({dim}, {lo}, {hi})"),
        }
    }
}

/// Validate a chain against a machine shape: the shape of the GPU space
/// after applying every op.
pub fn chain_shape(chain: &[ChainOp], desc: &MachineDesc) -> Result<Vec<i64>, String> {
    let mut s = ProcSpace::machine(desc, ProcKind::Gpu);
    for op in chain {
        s = op.apply_space(&s)?;
    }
    Ok(s.size().0.clone())
}

/// A generated mapping function — the mapping half of the search space.
/// `None` in [`TuneSpec::mapping`] keeps the app's baseline functions.
#[derive(Clone, Debug, PartialEq)]
pub enum MapFn {
    /// Fig 12 hierarchical mapping over the `dims` leading iteration
    /// dimensions: decompose nodes over the task grid, then GPUs over the
    /// per-node sub-grid; block on node dims, cyclic on GPU dims.
    HierBlock { dims: usize },
    /// Row-major linearize the iteration point, then block over a 1-D
    /// transformed view.
    LinearBlock { chain: Vec<ChainOp> },
    /// Row-major linearize, then round-robin over a 1-D transformed view.
    LinearCyclic { chain: Vec<ChainOp> },
}

/// Name every generated mapping function shares.
pub const AUTO_FN: &str = "auto_map";

fn install_map_fn(b: &mut MapperBuilder, map_fn: &MapFn) {
    match map_fn {
        MapFn::HierBlock { dims } => {
            let m = b.machine("m", ProcKind::Gpu);
            let dims = *dims;
            let d = dims as i64;
            b.def_fn(AUTO_FN, move |f| {
                let (p, s) = (f.ipoint(), f.ispace());
                let head = f.bind("s_head", s.slice_to(d));
                let m_up = f.bind_view("m_up", m.auto_split(0, head.clone()));
                let sub = f.bind("sub", (head + m_up.sizes_to(-1) - 1i64) / m_up.sizes_to(-1));
                let m_full = f.bind_view("m_full", m_up.auto_split(dims, sub));
                let mut coords: Vec<VExpr> = Vec::with_capacity(2 * dims);
                for i in 0..d {
                    coords.push(p.idx(i) * m_full.size_at(i) / s.idx(i));
                }
                for i in 0..d {
                    coords.push(p.idx(i) % m_full.size_at(i + d));
                }
                f.ret(m_full.at(coords));
            });
        }
        MapFn::LinearBlock { chain } | MapFn::LinearCyclic { chain } => {
            let m = b.machine("m", ProcKind::Gpu);
            let mut v = m;
            for op in chain {
                v = op.apply_view(&v);
            }
            let flat = b.view("m_t", v);
            let block = matches!(map_fn, MapFn::LinearBlock { .. });
            b.def_fn(AUTO_FN, move |f| {
                let (p, s) = (f.ipoint(), f.ispace());
                let lin = f.bind("lin", VExpr::linearize(p, s.clone()));
                let coord = if block {
                    lin * flat.size_at(0) / VExpr::prod(s)
                } else {
                    lin % flat.size_at(0)
                };
                f.ret(flat.at([coord]));
            });
        }
    }
    b.index_task_map("default", AUTO_FN);
}

fn map_fn_mpl(map_fn: &MapFn) -> String {
    let mut s = String::new();
    match map_fn {
        MapFn::HierBlock { dims } => {
            let d = *dims;
            s.push_str("m = Machine(GPU)\n\n");
            let _ = writeln!(s, "def {AUTO_FN}(Tuple ipoint, Tuple ispace):");
            let _ = writeln!(s, "    s_head = ispace[:{d}]");
            s.push_str("    m_up = m.decompose(0, s_head)\n");
            s.push_str("    sub = (s_head + m_up[:-1] - 1) / m_up[:-1]\n");
            let _ = writeln!(s, "    m_full = m_up.decompose({d}, sub)");
            let mut coords = Vec::with_capacity(2 * d);
            for i in 0..d {
                coords.push(format!("ipoint[{i}] * m_full.size[{i}] / ispace[{i}]"));
            }
            for i in 0..d {
                coords.push(format!("ipoint[{i}] % m_full.size[{}]", i + d));
            }
            let _ = writeln!(s, "    return m_full[{}]", coords.join(", "));
        }
        MapFn::LinearBlock { chain } | MapFn::LinearCyclic { chain } => {
            s.push_str("m = Machine(GPU)\n");
            let ops: String = chain.iter().map(|op| op.mpl()).collect();
            let _ = writeln!(s, "m_t = m{ops}");
            s.push('\n');
            let _ = writeln!(s, "def {AUTO_FN}(Tuple ipoint, Tuple ispace):");
            s.push_str("    lin = linearize(ipoint, ispace)\n");
            if matches!(map_fn, MapFn::LinearBlock { .. }) {
                s.push_str("    return m_t[lin * m_t.size[0] / prod(ispace)]\n");
            } else {
                s.push_str("    return m_t[lin % m_t.size[0]]\n");
            }
        }
    }
    let _ = writeln!(s, "\nIndexTaskMap default {AUTO_FN}");
    s
}

/// A candidate mapper in the tuner's search space.
#[derive(Clone, Debug, PartialEq)]
pub struct TuneSpec {
    /// Application the genome targets (selects the seed mapping).
    pub app: String,
    /// `None` keeps the app's baseline mapping functions; `Some` replaces
    /// the `default` index mapping with a generated one.
    pub mapping: Option<MapFn>,
    /// Communication objective for every decompose in the mapper.
    pub objective: Objective,
    /// `TaskMap` directives: task family → processor kind.
    pub task_proc: BTreeMap<String, ProcKind>,
    /// `Region` directives: (task family, arg) → memory kind.
    pub mem: BTreeMap<(String, usize), MemKind>,
    /// `GarbageCollect` directives.
    pub gc: BTreeSet<(String, usize)>,
    /// `Backpressure` directives: task family → in-flight limit.
    pub backpressure: BTreeMap<String, usize>,
}

impl TuneSpec {
    /// The seed genome: the app's baseline Mapple mapper, verbatim —
    /// baseline mapping functions, isotropic objective, no policy
    /// directives. Search always starts here, and the tuner never
    /// returns anything scored worse.
    pub fn seed(app: &str) -> TuneSpec {
        TuneSpec {
            app: app.to_string(),
            mapping: None,
            objective: Objective::Isotropic,
            task_proc: BTreeMap::new(),
            mem: BTreeMap::new(),
            gc: BTreeSet::new(),
            backpressure: BTreeMap::new(),
        }
    }

    /// Number of directive edits relative to the seed (reporting).
    pub fn edits(&self) -> usize {
        usize::from(self.mapping.is_some())
            + usize::from(self.objective != Objective::Isotropic)
            + self.task_proc.len()
            + self.mem.len()
            + self.gc.len()
            + self.backpressure.len()
    }

    /// Compile the genome into a [`MapperSpec`] bound to a machine, via
    /// the same typed-op builder path as every other mapper.
    pub fn build(&self, desc: &MachineDesc) -> Result<MapperSpec, String> {
        let mut b = MapperBuilder::new(desc);
        b.with_objective(self.objective.clone());
        match &self.mapping {
            None => builder_mappers::install_mapping(&mut b, &self.app)?,
            Some(f) => install_map_fn(&mut b, f),
        }
        for (task, kind) in &self.task_proc {
            b.task_map(task, *kind);
        }
        for ((task, arg), mem) in &self.mem {
            let scope = self.task_proc.get(task).copied().unwrap_or(ProcKind::Gpu);
            b.region(task, *arg, scope, *mem);
        }
        for (task, arg) in &self.gc {
            b.garbage_collect(task, *arg);
        }
        for (task, limit) in &self.backpressure {
            b.backpressure(task, *limit);
        }
        b.build()
    }

    /// Pretty-print the genome as `.mpl` source. Recompiling the result
    /// with [`MapperSpec::compile_with`] (passing [`TuneSpec::objective`],
    /// which has no surface syntax) reproduces the built spec's decisions
    /// — see `rust/tests/tune.rs`. The `# tune.*` comment lines carry the
    /// genome knobs that have no directive surface (mapping template,
    /// objective), so [`TuneSpec::from_mpl`] can warm-start a later run
    /// (`tune --resume`) from the emitted file.
    pub fn to_mpl(&self) -> Result<String, String> {
        let mut s = String::new();
        let _ = writeln!(s, "# autotuned mapper for {} (crate::tune)", self.app);
        let _ = writeln!(s, "# tune.objective: {}", fmt_objective(&self.objective));
        let _ = writeln!(s, "# tune.mapping: {}", fmt_mapping(self.mapping.as_ref()));
        match &self.mapping {
            None => {
                let base = crate::apps::mappers::mapple_source(&self.app)
                    .ok_or_else(|| format!("no baseline mapper for app '{}'", self.app))?;
                s.push_str(base.trim_end());
                s.push('\n');
            }
            Some(f) => {
                s.push_str(map_fn_mpl(f).trim_end());
                s.push('\n');
            }
        }
        for (task, kind) in &self.task_proc {
            let _ = writeln!(s, "TaskMap {task} {kind}");
        }
        for ((task, arg), mem) in &self.mem {
            let scope = self.task_proc.get(task).copied().unwrap_or(ProcKind::Gpu);
            let _ = writeln!(s, "Region {task} arg{arg} {scope} {mem}");
        }
        for (task, arg) in &self.gc {
            let _ = writeln!(s, "GarbageCollect {task} arg{arg}");
        }
        for (task, limit) in &self.backpressure {
            let _ = writeln!(s, "Backpressure {task} {limit}");
        }
        Ok(s)
    }

    /// Reconstruct a genome from a previously emitted `.mpl` — the warm
    /// start behind `tune --resume`. The mapping template and objective
    /// come from the `# tune.*` comment lines (absent in hand-written
    /// files: baseline mapping, isotropic objective); the directive
    /// tables are recovered by round-tripping the source through
    /// [`MapperSpec::compile_with`]. The result is validated by building
    /// it against `desc`.
    pub fn from_mpl(app: &str, src: &str, desc: &MachineDesc) -> Result<TuneSpec, String> {
        let mut objective = Objective::Isotropic;
        let mut mapping: Option<MapFn> = None;
        for line in src.lines() {
            let line = line.trim();
            if let Some(rest) = line.strip_prefix("# tune.objective:") {
                objective = parse_objective(rest.trim())?;
            } else if let Some(rest) = line.strip_prefix("# tune.mapping:") {
                mapping = parse_mapping(rest.trim())?;
            }
        }
        let spec = MapperSpec::compile_with(src, desc, objective.clone())
            .map_err(|e| format!("resumed source does not compile: {e}"))?;
        let mut g = TuneSpec::seed(app);
        g.objective = objective;
        g.mapping = mapping;
        for (task, kind) in &spec.task_maps {
            g.task_proc.insert(task.clone(), *kind);
        }
        for (task, args) in &spec.regions {
            for (arg, (_scope, mem)) in args {
                g.mem.insert((task.clone(), *arg), *mem);
            }
        }
        for (task, args) in &spec.gc {
            for arg in args {
                g.gc.insert((task.clone(), *arg));
            }
        }
        for (task, limit) in &spec.backpressure {
            g.backpressure.insert(task.clone(), *limit);
        }
        g.build(desc).map_err(|e| format!("resumed genome does not build: {e}"))?;
        Ok(g)
    }
}

/// `# tune.objective:` serialization (round-trips via [`parse_objective`]).
fn fmt_objective(o: &Objective) -> String {
    fn list(v: &[f64]) -> String {
        v.iter().map(|x| x.to_string()).collect::<Vec<_>>().join(",")
    }
    match o {
        Objective::Isotropic => "isotropic".to_string(),
        Objective::AnisotropicHalo(h) => format!("aniso:{}", list(h)),
        Objective::WithTranspose { halo, transpose_dims } => format!(
            "transpose:{};{}",
            list(halo),
            transpose_dims
                .iter()
                .map(|&d| if d { "1" } else { "0" })
                .collect::<Vec<_>>()
                .join(",")
        ),
    }
}

fn parse_objective(s: &str) -> Result<Objective, String> {
    fn list(s: &str) -> Result<Vec<f64>, String> {
        s.split(',')
            .map(|x| x.trim().parse::<f64>().map_err(|_| format!("bad objective weight '{x}'")))
            .collect()
    }
    if s == "isotropic" {
        return Ok(Objective::Isotropic);
    }
    if let Some(rest) = s.strip_prefix("aniso:") {
        return Ok(Objective::AnisotropicHalo(list(rest)?));
    }
    if let Some(rest) = s.strip_prefix("transpose:") {
        let (h, d) = rest
            .split_once(';')
            .ok_or_else(|| format!("bad transpose objective '{rest}'"))?;
        return Ok(Objective::WithTranspose {
            halo: list(h)?,
            transpose_dims: d.split(',').map(|x| x.trim() == "1").collect(),
        });
    }
    Err(format!("unknown tune.objective '{s}'"))
}

/// `# tune.mapping:` serialization (round-trips via [`parse_mapping`]).
fn fmt_mapping(m: Option<&MapFn>) -> String {
    match m {
        None => "seed".to_string(),
        Some(MapFn::HierBlock { dims }) => format!("hier:{dims}"),
        Some(MapFn::LinearBlock { chain }) => {
            format!("linear_block:{}", chain.iter().map(|op| op.mpl()).collect::<String>())
        }
        Some(MapFn::LinearCyclic { chain }) => {
            format!("linear_cyclic:{}", chain.iter().map(|op| op.mpl()).collect::<String>())
        }
    }
}

fn parse_mapping(s: &str) -> Result<Option<MapFn>, String> {
    if s == "seed" {
        return Ok(None);
    }
    if let Some(d) = s.strip_prefix("hier:") {
        let dims =
            d.trim().parse::<usize>().map_err(|_| format!("bad hier dims '{d}'"))?;
        return Ok(Some(MapFn::HierBlock { dims }));
    }
    if let Some(rest) = s.strip_prefix("linear_block:") {
        return Ok(Some(MapFn::LinearBlock { chain: parse_chain(rest)? }));
    }
    if let Some(rest) = s.strip_prefix("linear_cyclic:") {
        return Ok(Some(MapFn::LinearCyclic { chain: parse_chain(rest)? }));
    }
    Err(format!("unknown tune.mapping '{s}'"))
}

/// Parse a `.split(0, 2).merge(0, 1)` transform chain.
fn parse_chain(s: &str) -> Result<Vec<ChainOp>, String> {
    let mut out = Vec::new();
    for seg in s.split('.') {
        let seg = seg.trim();
        if seg.is_empty() {
            continue;
        }
        let (name, rest) =
            seg.split_once('(').ok_or_else(|| format!("bad chain op '{seg}'"))?;
        let rest = rest.strip_suffix(')').ok_or_else(|| format!("bad chain op '{seg}'"))?;
        let nums: Vec<i64> = rest
            .split(',')
            .map(|x| x.trim().parse::<i64>().map_err(|_| format!("bad chain arg in '{seg}'")))
            .collect::<Result<_, _>>()?;
        let op = match (name, nums.as_slice()) {
            ("split", [dim, factor]) => ChainOp::Split { dim: *dim as usize, factor: *factor },
            ("merge", [p, q]) => ChainOp::Merge { p: *p as usize, q: *q as usize },
            ("swap", [p, q]) => ChainOp::Swap { p: *p as usize, q: *q as usize },
            ("slice", [dim, lo, hi]) => {
                ChainOp::Slice { dim: *dim as usize, lo: *lo, hi: *hi }
            }
            _ => return Err(format!("unknown chain op '{seg}'")),
        };
        out.push(op);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::machine::point::{Rect, Tuple};

    fn desc(nodes: usize, gpus: usize) -> MachineDesc {
        let mut d = MachineDesc::paper_testbed(nodes);
        d.gpus_per_node = gpus;
        d
    }

    #[test]
    fn seed_builds_and_matches_baseline_text() {
        let d = desc(2, 4);
        for app in builder_mappers::BUILT_APPS {
            let spec = TuneSpec::seed(app).build(&d).unwrap_or_else(|e| panic!("{app}: {e}"));
            let text = MapperSpec::compile(
                crate::apps::mappers::mapple_source(app).unwrap(),
                &d,
            )
            .unwrap();
            let dom = Rect::from_extent(&Tuple::from([4, 4]));
            // spot-check equal placements for a 2D launch on 2D-capable apps
            if !matches!(*app, "johnson" | "solomonik" | "cosma") {
                assert_eq!(
                    spec.plan_domain("anytask", &dom).unwrap(),
                    text.plan_domain("anytask", &dom).unwrap(),
                    "{app}"
                );
            }
            assert_eq!(spec.index_task_maps, text.index_task_maps, "{app}");
            assert!(spec.regions.is_empty() && spec.gc.is_empty(), "{app}: seed has no policies");
        }
    }

    #[test]
    fn generated_map_fns_build_and_roundtrip() {
        let d = desc(2, 4);
        let cases = [
            MapFn::HierBlock { dims: 1 },
            MapFn::HierBlock { dims: 2 },
            MapFn::LinearBlock {
                chain: vec![ChainOp::Swap { p: 0, q: 1 }, ChainOp::Merge { p: 0, q: 1 }],
            },
            MapFn::LinearCyclic { chain: vec![ChainOp::Merge { p: 0, q: 1 }] },
            MapFn::LinearBlock {
                chain: vec![
                    ChainOp::Split { dim: 1, factor: 2 },
                    ChainOp::Merge { p: 0, q: 1 },
                    ChainOp::Merge { p: 0, q: 1 },
                ],
            },
        ];
        for map_fn in cases {
            let mut g = TuneSpec::seed("cannon");
            g.mapping = Some(map_fn.clone());
            g.gc.insert(("mm_step".into(), 0));
            g.mem.insert(("mm_step".into(), 1), MemKind::ZeroCopy);
            let built = g.build(&d).unwrap_or_else(|e| panic!("{map_fn:?}: {e}"));
            let text =
                MapperSpec::compile_with(&g.to_mpl().unwrap(), &d, g.objective.clone())
                    .unwrap_or_else(|e| panic!("{map_fn:?}: emitted source: {e}"));
            for ispace in [Tuple::from([8, 8]), Tuple::from([6, 10])] {
                let dom = Rect::from_extent(&ispace);
                assert_eq!(
                    built.plan_domain("mm_step_0", &dom).unwrap(),
                    text.plan_domain("mm_step_0", &dom).unwrap(),
                    "{map_fn:?} {ispace:?}"
                );
            }
            assert_eq!(built.regions, text.regions, "{map_fn:?}");
            assert_eq!(built.gc, text.gc, "{map_fn:?}");
        }
    }

    #[test]
    fn hier3d_builds_and_roundtrips_on_3d_launches() {
        // 3D-launch apps (min_dims == 3 is possible for e.g. johnson-like
        // workloads) can win with HierBlock{3}; its emitted .mpl must
        // recompile to identical placements like the 1D/2D variants.
        let d = desc(2, 4);
        let mut g = TuneSpec::seed("solomonik");
        g.mapping = Some(MapFn::HierBlock { dims: 3 });
        let built = g.build(&d).unwrap();
        let text = MapperSpec::compile_with(&g.to_mpl().unwrap(), &d, g.objective.clone())
            .unwrap_or_else(|e| panic!("emitted hier3d source: {e}"));
        for ispace in [Tuple::from([4, 4, 4]), Tuple::from([2, 3, 5])] {
            let dom = Rect::from_extent(&ispace);
            let a = built.plan_domain("mm25d_0", &dom).unwrap();
            let b = text.plan_domain("mm25d_0", &dom).unwrap();
            assert_eq!(a, b, "{ispace:?}");
        }
        // sanity: spreads across the machine on a big-enough launch
        let dom = Rect::from_extent(&Tuple::from([4, 4, 4]));
        let uniq: std::collections::HashSet<_> =
            built.plan_domain("t", &dom).unwrap().procs().iter().copied().collect();
        assert!(uniq.len() > 1, "{uniq:?}");
    }

    #[test]
    fn hier1d_works_on_1d_launches() {
        let d = desc(2, 4);
        let mut g = TuneSpec::seed("circuit");
        g.mapping = Some(MapFn::HierBlock { dims: 1 });
        let spec = g.build(&d).unwrap();
        let dom = Rect::from_extent(&Tuple::from([16]));
        let table = spec.plan_domain("calc_new_currents", &dom).unwrap();
        let uniq: std::collections::HashSet<_> = table.procs().iter().collect();
        assert!(uniq.len() > 1, "spreads over processors: {uniq:?}");
    }

    #[test]
    fn from_mpl_roundtrips_full_genomes() {
        let d = desc(2, 4);
        let cases = [
            {
                let mut g = TuneSpec::seed("cannon");
                g.mapping = Some(MapFn::HierBlock { dims: 2 });
                g.objective = Objective::AnisotropicHalo(vec![4.0, 1.0]);
                g.gc.insert(("mm_step".into(), 0));
                g.mem.insert(("mm_step".into(), 1), MemKind::ZeroCopy);
                g.backpressure.insert("mm_step".into(), 2);
                g
            },
            {
                let mut g = TuneSpec::seed("cannon");
                g.mapping = Some(MapFn::LinearBlock {
                    chain: vec![ChainOp::Swap { p: 0, q: 1 }, ChainOp::Merge { p: 0, q: 1 }],
                });
                g.task_proc.insert("init_a".into(), ProcKind::Cpu);
                g
            },
            TuneSpec::seed("cannon"),
        ];
        for g in cases {
            let mpl = g.to_mpl().unwrap();
            let back = TuneSpec::from_mpl("cannon", &mpl, &d)
                .unwrap_or_else(|e| panic!("{g:?}: {e}"));
            assert_eq!(back, g, "genome must round-trip through .mpl");
        }
    }

    #[test]
    fn from_mpl_accepts_plain_sources_as_baseline() {
        // A hand-written mapper without tune.* comments resumes as the
        // baseline mapping with its directives imported.
        let d = desc(2, 4);
        let src = crate::apps::mappers::mapple_source("cannon").unwrap();
        let g = TuneSpec::from_mpl("cannon", src, &d).unwrap();
        assert_eq!(g.mapping, None);
        assert_eq!(g.objective, Objective::Isotropic);
    }

    #[test]
    fn chain_shape_validates() {
        let d = desc(2, 4);
        let ok = vec![ChainOp::Swap { p: 0, q: 1 }, ChainOp::Merge { p: 0, q: 1 }];
        assert_eq!(chain_shape(&ok, &d).unwrap(), vec![8]);
        let bad = vec![ChainOp::Split { dim: 0, factor: 3 }]; // 3 ∤ 2 nodes
        assert!(chain_shape(&bad, &d).is_err());
    }

    #[test]
    fn objective_changes_decompose_choice() {
        // On a 2:1-halo-weighted objective the node grid for a square
        // space should differ from (or equal) the isotropic one but both
        // must build; placements must still cover all procs.
        let d = desc(4, 4);
        let mut g = TuneSpec::seed("cannon");
        g.objective = Objective::AnisotropicHalo(vec![4.0, 1.0]);
        let spec = g.build(&d).unwrap();
        let dom = Rect::from_extent(&Tuple::from([8, 8]));
        let table = spec.plan_domain("mm_step_0", &dom).unwrap();
        let uniq: std::collections::HashSet<_> = table.procs().iter().collect();
        assert_eq!(uniq.len(), 16, "all 16 GPUs used");
    }
}
