//! Search strategies behind one [`Strategy`] trait: propose a batch,
//! observe the scores, repeat. The driver owns the budget, the RNG, and
//! the parallel evaluation; strategies are pure proposal/selection logic,
//! which keeps every strategy deterministic under a fixed seed no matter
//! how many worker threads score the batch.

use super::space::SearchSpace;
use super::spec::TuneSpec;
use crate::machine::topology::MachineDesc;
use crate::util::prng::Rng;
use std::cmp::Ordering;

/// A search strategy. `propose` may return fewer candidates than asked
/// (never more); an empty proposal ends the run early.
pub trait Strategy {
    fn name(&self) -> &'static str;

    fn propose(
        &mut self,
        rng: &mut Rng,
        space: &SearchSpace,
        shapes: &[MachineDesc],
        batch: usize,
    ) -> Vec<TuneSpec>;

    fn observe(&mut self, scored: &[(TuneSpec, f64)]);
}

/// Which strategy to run (CLI / config surface).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StrategyKind {
    /// Independent mutations of the seed each round.
    Random,
    /// Beam search of the given width; width 1 is greedy hill-climbing.
    Beam(usize),
}

impl StrategyKind {
    pub fn parse(s: &str) -> Result<StrategyKind, String> {
        match s {
            "random" => Ok(StrategyKind::Random),
            "greedy" => Ok(StrategyKind::Beam(1)),
            "beam" => Ok(StrategyKind::Beam(4)),
            other => match other.strip_prefix("beam") {
                Some(w) => w
                    .parse::<usize>()
                    .map(|w| StrategyKind::Beam(w.max(1)))
                    .map_err(|_| format!("bad strategy '{other}'")),
                None => Err(format!("unknown strategy '{other}' (random|greedy|beam|beamN)")),
            },
        }
    }

    /// Instantiate the strategy, rooted at the seed genome.
    pub fn build(&self, seed: TuneSpec) -> Box<dyn Strategy> {
        match *self {
            StrategyKind::Random => Box::new(RandomSearch { seed }),
            StrategyKind::Beam(width) => {
                Box::new(BeamSearch { width: width.max(1), seed, beam: Vec::new() })
            }
        }
    }
}

/// Pure random search: every candidate is a fresh mutation of the seed.
pub struct RandomSearch {
    seed: TuneSpec,
}

impl Strategy for RandomSearch {
    fn name(&self) -> &'static str {
        "random"
    }

    fn propose(
        &mut self,
        rng: &mut Rng,
        space: &SearchSpace,
        shapes: &[MachineDesc],
        batch: usize,
    ) -> Vec<TuneSpec> {
        (0..batch).map(|_| space.mutate(&self.seed, rng, shapes)).collect()
    }

    fn observe(&mut self, _scored: &[(TuneSpec, f64)]) {}
}

/// Beam search / greedy refinement: keep the `width` best genomes seen,
/// propose mutations of the beam round-robin, fold survivors back in.
pub struct BeamSearch {
    width: usize,
    seed: TuneSpec,
    beam: Vec<(TuneSpec, f64)>,
}

impl Strategy for BeamSearch {
    fn name(&self) -> &'static str {
        "beam"
    }

    fn propose(
        &mut self,
        rng: &mut Rng,
        space: &SearchSpace,
        shapes: &[MachineDesc],
        batch: usize,
    ) -> Vec<TuneSpec> {
        let mut out = Vec::with_capacity(batch);
        for i in 0..batch {
            let parent = if self.beam.is_empty() {
                &self.seed
            } else {
                &self.beam[i % self.beam.len()].0
            };
            out.push(space.mutate(parent, rng, shapes));
        }
        out
    }

    fn observe(&mut self, scored: &[(TuneSpec, f64)]) {
        for (spec, v) in scored {
            if !v.is_finite() {
                continue;
            }
            if self.beam.iter().any(|(b, _)| b == spec) {
                continue; // already on the beam
            }
            self.beam.push((spec.clone(), *v));
        }
        // Stable sort: earlier (older) entries win ties, keeping the run
        // deterministic and biased toward simpler, earlier-found genomes.
        self.beam.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap_or(Ordering::Equal));
        self.beam.truncate(self.width);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;

    fn fixture() -> (SearchSpace, Vec<MachineDesc>, TuneSpec) {
        let inst = apps::cannon(256, 8);
        (
            SearchSpace::from_app("cannon", &inst),
            vec![MachineDesc::paper_testbed(2)],
            TuneSpec::seed("cannon"),
        )
    }

    #[test]
    fn strategy_kind_parses() {
        assert_eq!(StrategyKind::parse("random").unwrap(), StrategyKind::Random);
        assert_eq!(StrategyKind::parse("greedy").unwrap(), StrategyKind::Beam(1));
        assert_eq!(StrategyKind::parse("beam").unwrap(), StrategyKind::Beam(4));
        assert_eq!(StrategyKind::parse("beam8").unwrap(), StrategyKind::Beam(8));
        assert!(StrategyKind::parse("anneal").is_err());
    }

    #[test]
    fn random_proposes_batch() {
        let (space, shapes, seed) = fixture();
        let mut s = StrategyKind::Random.build(seed);
        let mut rng = Rng::new(1);
        let got = s.propose(&mut rng, &space, &shapes, 7);
        assert_eq!(got.len(), 7);
    }

    #[test]
    fn beam_keeps_best_and_dedups() {
        let (space, shapes, seed) = fixture();
        let mut s = BeamSearch { width: 2, seed: seed.clone(), beam: Vec::new() };
        let mut a = seed.clone();
        a.gc.insert(("mm_step".into(), 0));
        let mut b = seed.clone();
        b.gc.insert(("mm_step".into(), 1));
        s.observe(&[
            (seed.clone(), 5.0),
            (a.clone(), 3.0),
            (b.clone(), 4.0),
            (a.clone(), 3.0),        // duplicate genome: ignored
            (seed.clone(), f64::INFINITY), // invalid: ignored (already present anyway)
        ]);
        assert_eq!(s.beam.len(), 2);
        assert_eq!(s.beam[0].0, a);
        assert_eq!(s.beam[1].0, b);
        // proposals now mutate beam parents
        let mut rng = Rng::new(2);
        let got = s.propose(&mut rng, &space, &shapes, 4);
        assert_eq!(got.len(), 4);
    }
}
