//! `mapple tune --validate`: does the simulator's ranking survive
//! contact with reality?
//!
//! The tuner orders genomes by *simulated* geomean makespan. Validation
//! re-scores the top-N of that ranking with real measured runs
//! ([`crate::apps::exec_app`] wall clock, geomean across the same
//! shapes) and reports how well the modelled order predicts the
//! measured one:
//!
//! - **Spearman ρ** — Pearson correlation of the two rank vectors
//!   (tie-averaged); sensitive to how far entries moved.
//! - **Kendall τ** (tau-b) — fraction of concordant minus discordant
//!   pairs; sensitive to how many pairs flipped.
//! - **Inversions** — the discordant pairs themselves, as `(i, j)` sim
//!   ranks, so a report names exactly which modelled comparisons the
//!   measurement contradicts.
//!
//! [`validate_ranking`] takes the measurement as a closure so tests can
//! inject a deterministic pseudo-measurement (bitwise-repeatable
//! correlations under a fixed seed); [`validate_exec`] is the CLI
//! binding that measures for real.

use super::score::EvalCtx;
use super::spec::TuneSpec;
use super::{TuneConfig, TuneResult};
use crate::apps::exec_app;
use crate::exec::ExecOptions;
use crate::mapper::MappleMapper;
use crate::util::json::Json;
use crate::util::stats::{kendall, spearman};

/// One re-measured genome in a [`ValidationReport`].
#[derive(Clone, Debug)]
pub struct ValidatedCandidate {
    /// Position in the simulator's ranking (0 = predicted best).
    pub rank_sim: usize,
    /// Simulated score (geomean makespan, seconds).
    pub sim_score: f64,
    /// Measured score (geomean wall clock, seconds).
    pub measured: f64,
    /// The genome as `.mpl` source (what you would actually run).
    pub mpl: String,
}

/// Rank-correlation report between simulated and measured orderings.
#[derive(Clone, Debug)]
pub struct ValidationReport {
    pub app: String,
    /// In simulated-rank order (rank_sim == index).
    pub candidates: Vec<ValidatedCandidate>,
    /// Spearman rank correlation of sim vs measured scores.
    pub spearman: f64,
    /// Kendall tau-b of sim vs measured scores.
    pub kendall: f64,
    /// Discordant `(i, j)` sim-rank pairs: sim says i beats j, the
    /// measurement says otherwise.
    pub inversions: Vec<(usize, usize)>,
}

impl ValidationReport {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("app", Json::Str(self.app.clone())),
            (
                "candidates",
                Json::arr(self.candidates.iter().map(|c| {
                    Json::obj(vec![
                        ("rank_sim", Json::Num(c.rank_sim as f64)),
                        ("sim_score", Json::Num(c.sim_score)),
                        ("measured", Json::Num(c.measured)),
                        ("mpl", Json::Str(c.mpl.clone())),
                    ])
                })),
            ),
            ("spearman", Json::Num(self.spearman)),
            ("kendall", Json::Num(self.kendall)),
            (
                "inversions",
                Json::arr(self.inversions.iter().map(|&(i, j)| {
                    Json::arr(vec![Json::Num(i as f64), Json::Num(j as f64)])
                })),
            ),
        ])
    }
}

/// Re-score the head of a simulated ranking with `measure` and compute
/// the rank correlations. `ranked` must be sorted by simulated score
/// ascending ([`TuneResult::ranked`] is); at least two candidates are
/// required for a correlation to exist.
pub fn validate_ranking(
    app: &str,
    ranked: &[(TuneSpec, f64)],
    top_n: usize,
    mut measure: impl FnMut(&TuneSpec) -> Result<f64, String>,
) -> Result<ValidationReport, String> {
    let n = top_n.min(ranked.len());
    if n < 2 {
        return Err(format!(
            "tune --validate: need at least 2 distinct finite-scoring genomes, have {}",
            ranked.len().min(top_n)
        ));
    }
    let head = &ranked[..n];
    let mut candidates = Vec::with_capacity(n);
    for (rank_sim, (spec, sim_score)) in head.iter().enumerate() {
        let measured = measure(spec)
            .map_err(|e| format!("tune --validate: measuring sim-rank {rank_sim}: {e}"))?;
        if !measured.is_finite() || measured <= 0.0 {
            return Err(format!(
                "tune --validate: measurement for sim-rank {rank_sim} is not a positive finite time ({measured})"
            ));
        }
        candidates.push(ValidatedCandidate {
            rank_sim,
            sim_score: *sim_score,
            measured,
            mpl: spec.to_mpl()?,
        });
    }
    let sim: Vec<f64> = candidates.iter().map(|c| c.sim_score).collect();
    let meas: Vec<f64> = candidates.iter().map(|c| c.measured).collect();
    let rho = spearman(&sim, &meas);
    let (tau, inversions) = kendall(&sim, &meas);
    Ok(ValidationReport {
        app: app.to_string(),
        candidates,
        spearman: rho,
        kendall: tau,
        inversions,
    })
}

/// CLI binding: measure each genome by building its mapper and running
/// the real executor over the tuning run's shapes (geomean wall clock,
/// with every run held to [`exec_app`]'s differential-verification
/// contract).
pub fn validate_exec(
    cfg: &TuneConfig,
    result: &TuneResult,
    top_n: usize,
    opts: &ExecOptions,
) -> Result<ValidationReport, String> {
    let ctx = EvalCtx::for_bench(&cfg.app, cfg.shapes.clone());
    validate_ranking(&cfg.app, &result.ranked, top_n, |spec| {
        let mut log_sum = 0.0f64;
        for (desc, app) in ctx.shapes.iter().zip(&ctx.apps) {
            let mapper_spec = spec.build(desc)?;
            let mapper = MappleMapper::new(mapper_spec);
            let out = exec_app(app, &mapper, desc, opts)?;
            log_sum += out.exec.wall_seconds.ln();
        }
        Ok((log_sum / ctx.shapes.len() as f64).exp())
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_ranked(n: usize) -> Vec<(TuneSpec, f64)> {
        (0..n).map(|i| (TuneSpec::seed("cannon"), 1.0 + i as f64)).collect()
    }

    #[test]
    fn perfect_agreement() {
        let ranked = fake_ranked(4);
        let mut calls = 0usize;
        let rep = validate_ranking("cannon", &ranked, 4, |_| {
            calls += 1;
            Ok(calls as f64) // measured order == sim order
        })
        .unwrap();
        assert_eq!(rep.spearman, 1.0);
        assert_eq!(rep.kendall, 1.0);
        assert!(rep.inversions.is_empty());
        assert_eq!(rep.candidates.len(), 4);
    }

    #[test]
    fn full_reversal() {
        let ranked = fake_ranked(4);
        let mut next = 4.0f64;
        let rep = validate_ranking("cannon", &ranked, 4, |_| {
            next -= 1.0;
            Ok(next + 1.0) // 4, 3, 2, 1: measured order reversed
        })
        .unwrap();
        assert_eq!(rep.spearman, -1.0);
        assert_eq!(rep.kendall, -1.0);
        assert_eq!(rep.inversions.len(), 6);
    }

    #[test]
    fn too_few_candidates_is_an_error() {
        let ranked = fake_ranked(1);
        assert!(validate_ranking("cannon", &ranked, 4, |_| Ok(1.0)).is_err());
    }
}
