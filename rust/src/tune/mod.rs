//! Simulator-guided mapper autotuning (the "LLM optimizers via
//! agent-system interfaces" follow-up, done with classic search): the
//! typed-op space PR 3 made first-class is small and discrete, and the
//! PR 2 simulator is a cheap, deterministic cost model — so mapper
//! tuning becomes search.
//!
//! ```text
//!   TuneSpec genome (tune::spec)       seed = baseline .mpl mapper
//!        │ mutate (tune::space)         over the app's task families
//!        ▼
//!   Strategy (tune::strategy)          random | greedy | beam
//!        │ propose batch
//!        ▼
//!   worker pool (tune::score)          std::thread::scope, no deps
//!        │ build → pipeline → sim      geomean makespan across shapes
//!        ▼
//!   TuneResult                         best genome + emitted .mpl
//! ```
//!
//! Guarantee: the seed genome is scored first and only strictly better
//! candidates replace it, so the returned mapper is never worse than the
//! app's baseline Mapple mapper *under the scored shapes* (tested in
//! `rust/tests/tune.rs`).

pub mod score;
pub mod space;
pub mod spec;
pub mod strategy;
pub mod validate;

pub use score::{evaluate_parallel, score, EvalCtx};
pub use space::SearchSpace;
pub use spec::{ChainOp, MapFn, TuneSpec};
pub use strategy::{BeamSearch, RandomSearch, Strategy, StrategyKind};
pub use validate::{validate_exec, validate_ranking, ValidatedCandidate, ValidationReport};

use crate::decompose::Objective;
use crate::machine::topology::MachineDesc;
use crate::util::prng::Rng;
use std::collections::HashMap;

/// Tuning-run parameters.
#[derive(Clone, Debug)]
pub struct TuneConfig {
    /// Application name (one of the nine benchmarks).
    pub app: String,
    /// Machine shapes candidates are scored on (geomean across them).
    pub shapes: Vec<MachineDesc>,
    /// RNG seed — the whole run is deterministic in it.
    pub seed: u64,
    /// Candidate evaluations after the seed genome.
    pub budget: usize,
    /// Candidates proposed (and scored in parallel) per round.
    pub batch: usize,
    /// Worker threads (0 = one per available core, capped at 8).
    pub threads: usize,
    pub strategy: StrategyKind,
    /// Warm-start genome (`tune --resume <file.mpl>`): scored first and
    /// folded into the strategy alongside the seed, so search continues
    /// from a previous run's winner instead of restarting cold. The
    /// never-worse-than-seed guarantee is unaffected.
    pub resume: Option<TuneSpec>,
}

impl TuneConfig {
    /// The default configuration benches and `Flavor::Auto` use: beam
    /// search over the single given shape with a fixed seed, sized to
    /// finish in seconds per app.
    pub fn quick(app: &str, desc: &MachineDesc) -> TuneConfig {
        TuneConfig {
            app: app.to_string(),
            shapes: vec![desc.clone()],
            seed: 0xA001,
            budget: 96,
            batch: 16,
            threads: 0,
            strategy: StrategyKind::Beam(4),
            resume: None,
        }
    }

    fn resolved_threads(&self) -> usize {
        if self.threads > 0 {
            return self.threads;
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(8)
    }
}

/// Outcome of a tuning run.
#[derive(Clone, Debug)]
pub struct TuneResult {
    /// Best genome found (== the seed when nothing beat it).
    pub best: TuneSpec,
    /// Its score (geomean simulated makespan, seconds).
    pub best_score: f64,
    /// The seed genome's score — `best_score <= seed_score` always.
    pub seed_score: f64,
    /// Candidates considered, seed excluded (duplicate genomes are
    /// served from a score memo instead of re-simulating).
    pub evaluated: usize,
    /// The best genome pretty-printed as `.mpl` source.
    pub mpl: String,
    /// The best genome's decompose objective — pass to
    /// [`crate::mapple::MapperSpec::compile_with`] when recompiling the
    /// emitted source (the objective has no surface syntax).
    pub objective: Objective,
    /// Every *distinct, finite-scoring* genome the run evaluated (seed
    /// and resume included), sorted by simulated score ascending with
    /// insertion order breaking ties. `mapple tune --validate` re-scores
    /// the head of this list with real measured runs.
    pub ranked: Vec<(TuneSpec, f64)>,
}

impl TuneResult {
    /// Speedup of the tuned mapper over the seed (≥ 1.0 by construction).
    pub fn speedup(&self) -> f64 {
        self.seed_score / self.best_score
    }
}

/// Run the autotuner against the benchmark-sized workload
/// ([`EvalCtx::for_bench`]). Deterministic in `cfg.seed`: the strategy
/// consumes the RNG single-threadedly and scoring is pure, so thread
/// count and scheduling cannot change the result.
pub fn tune(cfg: &TuneConfig) -> Result<TuneResult, String> {
    if cfg.shapes.is_empty() {
        return Err("tune: no machine shapes to score on".into());
    }
    if crate::apps::mappers::mapple_source(&cfg.app).is_none() {
        return Err(format!("tune: unknown app '{}' (see `mapple apps`)", cfg.app));
    }
    let ctx = EvalCtx::for_bench(&cfg.app, cfg.shapes.clone());
    tune_with_ctx(cfg, &ctx)
}

/// Run the autotuner against an explicit evaluation context — use this
/// when the workload being tuned for differs from the bench sizing
/// (e.g. `mapple run --mapper auto --scale N` tunes against the actual
/// scaled instance).
pub fn tune_with_ctx(cfg: &TuneConfig, ctx: &EvalCtx) -> Result<TuneResult, String> {
    if ctx.shapes.is_empty() {
        return Err("tune: no machine shapes to score on".into());
    }
    let space = SearchSpace::from_app(&cfg.app, &ctx.apps[0]);
    let seed_spec = TuneSpec::seed(&cfg.app);
    let seed_score = score(&seed_spec, ctx);
    if !seed_score.is_finite() {
        return Err(format!("tune: seed mapper for '{}' failed to simulate", cfg.app));
    }

    let mut rng = Rng::new(cfg.seed);
    let mut strat = cfg.strategy.build(seed_spec.clone());
    strat.observe(&[(seed_spec.clone(), seed_score)]);
    let threads = cfg.resolved_threads();

    // Score memo: mutation can propose a genome that was already scored
    // (e.g. an edit that undoes another); duplicates must not burn
    // simulator budget. Keyed by the genome's Debug rendering, which is
    // complete and deterministic.
    let mut seen: HashMap<String, f64> = HashMap::new();
    seen.insert(format!("{seed_spec:?}"), seed_score);

    // Distinct genomes in evaluation order; sorted into `ranked` at the
    // end. Infinite (invalid) scores are excluded — they cannot be
    // re-measured by `--validate`.
    let mut distinct: Vec<(TuneSpec, f64)> = vec![(seed_spec.clone(), seed_score)];

    let mut best = (seed_spec, seed_score);
    let mut evaluated = 0usize;

    // Warm start: score the resumed genome and fold it into the
    // strategy's state (the beam keeps it if it beats the seed).
    if let Some(resume) = &cfg.resume {
        if resume.app != cfg.app {
            return Err(format!(
                "tune: resume genome targets app '{}', not '{}'",
                resume.app, cfg.app
            ));
        }
        let v = score(resume, ctx);
        if !v.is_finite() {
            return Err("tune: resume genome fails to simulate on the scored shapes".into());
        }
        if seen.insert(format!("{resume:?}"), v).is_none() {
            distinct.push((resume.clone(), v));
        }
        strat.observe(&[(resume.clone(), v)]);
        if v < best.1 {
            best = (resume.clone(), v);
        }
        evaluated += 1;
    }

    while evaluated < cfg.budget {
        let want = cfg.batch.clamp(1, cfg.budget - evaluated);
        let cands = strat.propose(&mut rng, &space, &ctx.shapes, want);
        if cands.is_empty() {
            break;
        }
        // Resolve each candidate to a slot: Ok(score) from the memo, or
        // Err(index) into the deduplicated fresh list — identical genomes
        // inside one batch are simulated once.
        let keys: Vec<String> = cands.iter().map(|c| format!("{c:?}")).collect();
        let mut fresh: Vec<TuneSpec> = Vec::new();
        let mut fresh_of: HashMap<String, usize> = HashMap::new();
        let mut slots: Vec<Result<f64, usize>> = Vec::with_capacity(cands.len());
        for (c, key) in cands.iter().zip(&keys) {
            if let Some(&v) = seen.get(key) {
                slots.push(Ok(v));
            } else {
                let idx = *fresh_of.entry(key.clone()).or_insert_with(|| {
                    fresh.push(c.clone());
                    fresh.len() - 1
                });
                slots.push(Err(idx));
            }
        }
        let fresh_scores = evaluate_parallel(&fresh, ctx, threads);
        let scores: Vec<f64> = slots
            .iter()
            .map(|s| match s {
                Ok(v) => *v,
                Err(i) => fresh_scores[*i],
            })
            .collect();
        for (key, idx) in fresh_of {
            seen.insert(key, fresh_scores[idx]);
        }
        for (c, v) in fresh.iter().zip(&fresh_scores) {
            if v.is_finite() {
                distinct.push((c.clone(), *v));
            }
        }
        evaluated += cands.len();
        let scored: Vec<(TuneSpec, f64)> = cands.into_iter().zip(scores).collect();
        for (c, v) in &scored {
            if *v < best.1 {
                best = (c.clone(), *v);
            }
        }
        strat.observe(&scored);
    }

    let mpl = best.0.to_mpl()?;
    let mut ranked = distinct;
    ranked.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    Ok(TuneResult {
        objective: best.0.objective.clone(),
        best_score: best.1,
        seed_score,
        evaluated,
        mpl,
        best: best.0,
        ranked,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rejects_empty_shapes_and_unknown_app() {
        let mut cfg = TuneConfig::quick("cannon", &MachineDesc::paper_testbed(1));
        cfg.shapes.clear();
        assert!(tune(&cfg).is_err());
        let cfg = TuneConfig::quick("nope", &MachineDesc::paper_testbed(1));
        let e = tune(&cfg);
        assert!(e.is_err(), "{e:?}");
    }
}
