//! The tuner's search space: what an app exposes to mutate over, and the
//! mutation operators that move a [`TuneSpec`] through it.
//!
//! The space is derived from the app's *task program* (launch names,
//! region-argument counts, iteration-space arities), not hardcoded per
//! app — any `AppInstance` is tunable. Mutations are generated validated:
//! transform chains are checked against every machine shape the tuner
//! scores on, so candidates rarely waste an evaluation on a compile
//! error (runtime-invalid candidates still score `∞` and die off).

use super::spec::{chain_shape, ChainOp, MapFn, TuneSpec};
use crate::apps::AppInstance;
use crate::decompose::Objective;
use crate::machine::topology::{MachineDesc, MemKind, ProcKind};
use crate::mapple::program::base_name;
use crate::util::prng::Rng;
use std::collections::BTreeMap;

/// One task family of the app (launches sharing a directive family name).
#[derive(Clone, Debug)]
pub struct TaskInfo {
    /// Family name (`mm_step_3` → `mm_step`) — what directives target.
    pub family: String,
    /// Max region-argument count across the family's launches.
    pub args: usize,
    /// Max per-point FLOPs — biases the TaskMap mutation toward CPU for
    /// tiny tasks (paper §7.1: kernel-launch overhead dominates them).
    pub flops_per_point: f64,
}

/// Everything the mutation operators need to know about an app.
#[derive(Clone, Debug)]
pub struct SearchSpace {
    pub app: String,
    pub tasks: Vec<TaskInfo>,
    /// Smallest launch arity — bounds `HierBlock { dims }` proposals,
    /// since a generated mapping serves every launch.
    pub min_dims: usize,
}

/// Memory kinds a Region mutation may pick.
const MEM_MENU: &[MemKind] =
    &[MemKind::FbMem, MemKind::ZeroCopy, MemKind::SysMem, MemKind::RdmaMem];

/// Processor kinds a TaskMap mutation may pick.
const PROC_MENU: &[ProcKind] = &[ProcKind::Gpu, ProcKind::Cpu, ProcKind::Omp];

/// In-flight limits a Backpressure mutation may pick.
const BP_MENU: &[usize] = &[1, 2, 4, 8];

/// Decompose objectives the tuner searches over. Weight vectors are
/// adapted to each decompose call's arity via [`Objective::for_dims`].
pub fn objective_menu() -> Vec<Objective> {
    vec![
        Objective::Isotropic,
        Objective::AnisotropicHalo(vec![2.0, 1.0]),
        Objective::AnisotropicHalo(vec![1.0, 2.0]),
        Objective::AnisotropicHalo(vec![4.0, 1.0]),
        Objective::AnisotropicHalo(vec![1.0, 4.0]),
        Objective::WithTranspose { halo: vec![1.0, 1.0], transpose_dims: vec![true, false] },
        Objective::WithTranspose { halo: vec![1.0, 1.0], transpose_dims: vec![false, true] },
    ]
}

impl SearchSpace {
    /// Derive the search space from an app's task program.
    pub fn from_app(app: &str, inst: &AppInstance) -> SearchSpace {
        let mut families: BTreeMap<&str, TaskInfo> = BTreeMap::new();
        let mut min_dims = usize::MAX;
        for launch in &inst.launches {
            let fam = base_name(&launch.name);
            min_dims = min_dims.min(launch.domain.extent().dim());
            let entry = families.entry(fam).or_insert_with(|| TaskInfo {
                family: fam.to_string(),
                args: 0,
                flops_per_point: 0.0,
            });
            entry.args = entry.args.max(launch.reqs.len());
            entry.flops_per_point = entry.flops_per_point.max(launch.flops_per_point);
        }
        SearchSpace {
            app: app.to_string(),
            tasks: families.into_values().collect(),
            min_dims: if min_dims == usize::MAX { 1 } else { min_dims },
        }
    }

    /// One mutated child: 1–2 knob edits on a copy of `base`.
    pub fn mutate(
        &self,
        base: &TuneSpec,
        rng: &mut Rng,
        shapes: &[MachineDesc],
    ) -> TuneSpec {
        let mut out = base.clone();
        let edits = 1 + rng.below(2);
        for _ in 0..edits {
            self.mutate_once(&mut out, rng, shapes);
        }
        out
    }

    fn mutate_once(&self, spec: &mut TuneSpec, rng: &mut Rng, shapes: &[MachineDesc]) {
        if self.tasks.is_empty() {
            return;
        }
        match rng.below(12) {
            // --- mapping function -----------------------------------------
            0 => spec.mapping = None,
            1 | 2 => spec.mapping = Some(self.random_map_fn(rng, shapes)),
            // --- decompose objective --------------------------------------
            3 => {
                let menu = objective_menu();
                spec.objective = rng.choose(&menu).clone();
            }
            // --- memory placement -----------------------------------------
            4 | 5 => {
                let t = rng.choose(&self.tasks);
                if t.args == 0 {
                    return;
                }
                let key = (t.family.clone(), rng.below(t.args as u64) as usize);
                // Removal is only a real edit when the key exists;
                // otherwise fall through to an insert so the child
                // actually differs from its parent.
                let removed = rng.chance(0.25) && spec.mem.remove(&key).is_some();
                if !removed {
                    spec.mem.insert(key, *rng.choose(MEM_MENU));
                }
            }
            // --- eager collection -----------------------------------------
            6 | 7 => {
                let t = rng.choose(&self.tasks);
                if t.args == 0 {
                    return;
                }
                let key = (t.family.clone(), rng.below(t.args as u64) as usize);
                if !spec.gc.remove(&key) {
                    spec.gc.insert(key);
                }
            }
            // --- processor kind -------------------------------------------
            8 | 9 => {
                let t = rng.choose(&self.tasks);
                let removed = rng.chance(0.34) && spec.task_proc.remove(&t.family).is_some();
                if !removed {
                    // §7.1 heuristic as a proposal bias: tiny per-point
                    // tasks are dominated by GPU launch overhead, so for
                    // them propose CPU half the time.
                    let kind = if t.flops_per_point < 1e6 && rng.chance(0.5) {
                        ProcKind::Cpu
                    } else {
                        *rng.choose(PROC_MENU)
                    };
                    spec.task_proc.insert(t.family.clone(), kind);
                }
            }
            // --- backpressure ---------------------------------------------
            _ => {
                let t = rng.choose(&self.tasks);
                let removed = rng.chance(0.34) && spec.backpressure.remove(&t.family).is_some();
                if !removed {
                    spec.backpressure.insert(t.family.clone(), *rng.choose(BP_MENU));
                }
            }
        }
    }

    fn random_map_fn(&self, rng: &mut Rng, shapes: &[MachineDesc]) -> MapFn {
        let max_hier = self.min_dims.min(3);
        match rng.below(3) {
            0 if max_hier >= 1 => {
                MapFn::HierBlock { dims: 1 + rng.below(max_hier as u64) as usize }
            }
            1 => MapFn::LinearBlock { chain: random_chain(rng, shapes) },
            _ => MapFn::LinearCyclic { chain: random_chain(rng, shapes) },
        }
    }
}

/// A random transform chain over the 2-D GPU machine space that is valid
/// on every scored shape and ends one-dimensional (for linear mappings).
pub fn random_chain(rng: &mut Rng, shapes: &[MachineDesc]) -> Vec<ChainOp> {
    let mut chain: Vec<ChainOp> = Vec::new();
    // Optionally lead with the GPU-fastest reordering the shipped science
    // mappers use — a strong prior in this codebase.
    if rng.chance(0.5) {
        chain.push(ChainOp::Swap { p: 0, q: 1 });
    }
    let extra = rng.below(3);
    for _ in 0..extra {
        let Some(shape) = valid_shape(&chain, shapes) else { break };
        let n = shape.len();
        let op = match rng.below(4) {
            0 => {
                // split a composite dimension by one of its prime-ish factors
                let dim = rng.below(n as u64) as usize;
                let ext = min_extent(&chain, shapes, dim);
                match smallest_factor(ext) {
                    Some(f) => ChainOp::Split { dim, factor: f },
                    None => continue,
                }
            }
            1 if n >= 2 => {
                let p = rng.below((n - 1) as u64) as usize;
                ChainOp::Merge { p, q: p + 1 }
            }
            2 if n >= 2 => {
                let p = rng.below(n as u64) as usize;
                let mut q = rng.below(n as u64) as usize;
                if p == q {
                    q = (q + 1) % n;
                }
                ChainOp::Swap { p: p.min(q), q: p.max(q) }
            }
            _ => {
                // rare: slice away the tail half of a dimension
                if !rng.chance(0.25) {
                    continue;
                }
                let dim = rng.below(n as u64) as usize;
                let ext = min_extent(&chain, shapes, dim);
                if ext < 2 {
                    continue;
                }
                ChainOp::Slice { dim, lo: 0, hi: ext / 2 }
            }
        };
        let mut next = chain.clone();
        next.push(op);
        if valid_shape(&next, shapes).is_some() {
            chain = next;
        }
    }
    // Flatten to 1-D so the linear mappings can index it.
    loop {
        match valid_shape(&chain, shapes) {
            Some(shape) if shape.len() > 1 => {
                chain.push(ChainOp::Merge { p: 0, q: 1 });
            }
            Some(_) => break,
            None => {
                // Should not happen (every op was validated); fall back to
                // the plain GPU-fastest flattening.
                return vec![ChainOp::Swap { p: 0, q: 1 }, ChainOp::Merge { p: 0, q: 1 }];
            }
        }
    }
    chain
}

/// The chain's output shape on `shapes[0]`, provided the chain is valid
/// on *every* shape.
fn valid_shape(chain: &[ChainOp], shapes: &[MachineDesc]) -> Option<Vec<i64>> {
    let mut first = None;
    for (i, desc) in shapes.iter().enumerate() {
        match chain_shape(chain, desc) {
            Ok(s) if i == 0 => first = Some(s),
            Ok(_) => {}
            Err(_) => return None,
        }
    }
    first
}

/// Smallest extent of dimension `dim` across shapes (divisor proposals
/// must divide all of them — we use the gcd-ish conservative choice).
fn min_extent(chain: &[ChainOp], shapes: &[MachineDesc], dim: usize) -> i64 {
    let mut ext = i64::MAX;
    for desc in shapes {
        if let Ok(s) = chain_shape(chain, desc) {
            if let Some(&e) = s.get(dim) {
                ext = ext.min(e);
            }
        }
    }
    if ext == i64::MAX {
        1
    } else {
        ext
    }
}

/// Smallest prime factor > 1, if the extent is composite enough to split.
fn smallest_factor(ext: i64) -> Option<i64> {
    if ext < 2 {
        return None;
    }
    for f in 2..=ext {
        if f * f > ext {
            break;
        }
        if ext % f == 0 {
            return Some(f);
        }
    }
    // prime: splitting off the whole extent is legal ((ext, 1) shape)
    Some(ext)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;

    fn shapes() -> Vec<MachineDesc> {
        vec![MachineDesc::paper_testbed(2)]
    }

    #[test]
    fn space_from_app_finds_families() {
        let inst = apps::cannon(256, 8);
        let space = SearchSpace::from_app("cannon", &inst);
        assert!(space.tasks.iter().any(|t| t.family == "mm_step"), "{:?}", space.tasks);
        assert!(space.tasks.iter().all(|t| t.args > 0));
        assert_eq!(space.min_dims, 2);
    }

    #[test]
    fn random_chains_are_valid_and_flat() {
        let shapes = shapes();
        let mut rng = Rng::new(7);
        for _ in 0..200 {
            let chain = random_chain(&mut rng, &shapes);
            let shape = chain_shape(&chain, &shapes[0])
                .unwrap_or_else(|e| panic!("{chain:?}: {e}"));
            assert_eq!(shape.len(), 1, "{chain:?} → {shape:?}");
            assert!(shape[0] >= 1);
        }
    }

    #[test]
    fn mutations_build_mostly() {
        let inst = apps::cannon(256, 8);
        let space = SearchSpace::from_app("cannon", &inst);
        let shapes = shapes();
        let mut rng = Rng::new(11);
        let seed = TuneSpec::seed("cannon");
        let mut ok = 0;
        for _ in 0..100 {
            let cand = space.mutate(&seed, &mut rng, &shapes);
            if cand.build(&shapes[0]).is_ok() {
                ok += 1;
            }
        }
        assert!(ok >= 90, "only {ok}/100 mutated candidates compiled");
    }

    #[test]
    fn mutation_is_deterministic_per_seed() {
        let inst = apps::cannon(256, 8);
        let space = SearchSpace::from_app("cannon", &inst);
        let shapes = shapes();
        let seed = TuneSpec::seed("cannon");
        let a: Vec<TuneSpec> = {
            let mut rng = Rng::new(5);
            (0..20).map(|_| space.mutate(&seed, &mut rng, &shapes)).collect()
        };
        let b: Vec<TuneSpec> = {
            let mut rng = Rng::new(5);
            (0..20).map(|_| space.mutate(&seed, &mut rng, &shapes)).collect()
        };
        assert_eq!(a, b);
    }
}
