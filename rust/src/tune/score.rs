//! Candidate scoring: compile the genome, run the §5.1 pipeline and the
//! discrete-event simulator on every scored machine shape, and reduce to
//! one figure of merit (geometric-mean makespan; lower is better).
//!
//! Evaluation is pure — a candidate's score depends only on the genome
//! and the evaluation context — so batches are evaluated on a
//! `std::thread` worker pool (the crate is dependency-free; no rayon)
//! and results are bitwise deterministic regardless of thread count or
//! interleaving.

use super::spec::TuneSpec;
use crate::apps::{run_app, AppInstance};
use crate::machine::topology::MachineDesc;
use crate::mapper::MappleMapper;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// The fixed evaluation context a tuning run scores candidates against:
/// one app instance per machine shape (apps scale with the machine).
pub struct EvalCtx {
    pub app: String,
    pub shapes: Vec<MachineDesc>,
    pub apps: Vec<AppInstance>,
}

impl EvalCtx {
    /// Benchmark-sized context (the `bench::build_bench_app` scaling).
    pub fn for_bench(app: &str, shapes: Vec<MachineDesc>) -> EvalCtx {
        let apps = shapes.iter().map(|d| crate::bench::build_bench_app(app, d)).collect();
        EvalCtx { app: app.to_string(), shapes, apps }
    }

    /// Context over explicit instances (tests, custom workloads). The two
    /// vectors must be parallel.
    pub fn from_parts(app: &str, shapes: Vec<MachineDesc>, apps: Vec<AppInstance>) -> EvalCtx {
        assert_eq!(shapes.len(), apps.len(), "one app instance per machine shape");
        EvalCtx { app: app.to_string(), shapes, apps }
    }
}

/// Simulated figure of merit for one candidate: the geometric mean of
/// makespans across the context's shapes, `f64::INFINITY` when the
/// candidate fails to compile, errors at mapping time, or OOMs — invalid
/// candidates lose to every valid one.
pub fn score(spec: &TuneSpec, ctx: &EvalCtx) -> f64 {
    let mut log_sum = 0.0f64;
    for (desc, app) in ctx.shapes.iter().zip(&ctx.apps) {
        let mapper_spec = match spec.build(desc) {
            Ok(s) => s,
            Err(_) => return f64::INFINITY,
        };
        let mapper = MappleMapper::new(mapper_spec);
        match run_app(app, &mapper, desc) {
            Ok(out) if out.sim.oom.is_none() && out.sim.makespan > 0.0 => {
                log_sum += out.sim.makespan.ln();
            }
            _ => return f64::INFINITY,
        }
    }
    (log_sum / ctx.shapes.len() as f64).exp()
}

/// Score a batch of candidates on `threads` workers. Output order matches
/// input order; the result is identical to sequential evaluation.
pub fn evaluate_parallel(cands: &[TuneSpec], ctx: &EvalCtx, threads: usize) -> Vec<f64> {
    if cands.is_empty() {
        return Vec::new();
    }
    let threads = threads.clamp(1, cands.len());
    if threads == 1 {
        return cands.iter().map(|c| score(c, ctx)).collect();
    }
    let next = AtomicUsize::new(0);
    let out = Mutex::new(vec![f64::INFINITY; cands.len()]);
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= cands.len() {
                    break;
                }
                let v = score(&cands[i], ctx);
                out.lock().unwrap()[i] = v;
            });
        }
    });
    out.into_inner().unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;

    fn ctx() -> EvalCtx {
        let desc = MachineDesc::paper_testbed(1);
        let app = apps::cannon(256, 4);
        EvalCtx::from_parts("cannon", vec![desc], vec![app])
    }

    #[test]
    fn seed_scores_finite() {
        let c = ctx();
        let s = score(&TuneSpec::seed("cannon"), &c);
        assert!(s.is_finite() && s > 0.0, "{s}");
    }

    #[test]
    fn unknown_app_scores_infinite() {
        let c = ctx();
        assert!(score(&TuneSpec::seed("nope"), &c).is_infinite());
    }

    #[test]
    fn parallel_matches_sequential() {
        let c = ctx();
        let seed = TuneSpec::seed("cannon");
        let mut gc = seed.clone();
        gc.gc.insert(("mm_step".into(), 0));
        let mut bad = seed.clone();
        bad.app = "nope".into();
        let cands = vec![seed.clone(), gc, bad, seed];
        let seq: Vec<f64> = cands.iter().map(|x| score(x, &c)).collect();
        let par = evaluate_parallel(&cands, &c, 4);
        assert_eq!(seq.len(), par.len());
        for (a, b) in seq.iter().zip(&par) {
            assert!((a == b) || (a.is_infinite() && b.is_infinite()), "{a} vs {b}");
        }
    }
}
