//! Chaos engine: fault injection, failure detection, and
//! replan-the-suffix recovery for the concurrent executor.
//!
//! ```text
//!   FaultPlan + seed ──► inject (doomed-set, truncated lanes, drops)
//!          │                      │
//!          ▼                      ▼
//!   round 1: run with faults  +  heartbeat pumps + monitor (detect)
//!          │                      │
//!          ▼                      ▼ (joined before replanning)
//!   wipe dead stores ──► recover (lineage closure, re-placement,
//!          │              rerouted sends, survivor refetches)
//!          ▼
//!   round 2: rerun the lost suffix on survivors (exact versions)
//!          │
//!          ▼
//!   ExecResult — checksum bitwise equal to the failure-free oracle
//! ```
//!
//! Faults are *declarative*: a [`FaultPlan`] plus a seed fully determines
//! which node dies after how many of its tasks, which planned cross-node
//! sends are dropped or delayed, and which lanes stall. Injection is
//! resolved against the plan's global static order before any thread
//! starts, so the failure timeline, the recovery schedule, and the final
//! checksum are identical across worker counts and kernel tiers.
//!
//! Detection is physical, not declarative: per-node heartbeat pumps beat
//! over the same bounded channels that carry tiles, a dying node's pump
//! goes silent when its (truncated) lanes finish, and the monitor
//! declares death after `miss_threshold` missed intervals. The monitor
//! is joined before recovery planning begins — detection causally gates
//! recovery, exactly as it would in a real cluster.
//!
//! Recovery replans the unfinished suffix: every task whose execution or
//! output was lost re-runs on a survivor (planned placement preserved
//! for surviving nodes, dead nodes remapped round-robin), gather lists
//! are recomputed against the exact tile versions survivors still hold
//! (refetched where needed), and lost lineage re-executes bottom-up
//! (pure kernels + deterministic cold bases make recomputation exact).
//! The recovered run must satisfy [`ExecResult::verify_against`] with a
//! checksum bitwise equal to the failure-free run's.

pub(crate) mod detect;
pub(crate) mod inject;
pub(crate) mod recover;

use crate::exec::node::{self, Cluster, Pulse, RoundSpec};
use crate::exec::plan::{self, Key};
use crate::exec::{assemble_log, ExecOptions, ExecResult};
use crate::machine::topology::MachineDesc;
use crate::obs::{self, Cat};
use crate::serve::cache::PlanCache;
use crate::serve::proto::digest_hex;
use crate::sim::engine::MappingPolicies;
use crate::tasking::deps::{DataEnv, Dependences};
use crate::tasking::pipeline::{PipelineRun, PlanError};
use crate::tasking::task::IndexLaunch;
use crate::util::json::Json;
use std::collections::{HashMap, HashSet};
use std::fmt;
use std::time::Instant;

/// Kill one node after it completed `after` tasks of its share of the
/// plan's global static order.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Kill {
    pub node: usize,
    pub after: usize,
}

/// Delay a seeded `permille` fraction of planned cross-node sends by
/// `micros` microseconds (a delay storm — ordering pressure, no loss).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Delay {
    pub micros: u64,
    pub permille: u32,
}

/// Sleep `micros` before the `pos`-th task of the `lane`-th worker lane
/// of `node` (straggler injection; no semantic effect).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Stall {
    pub node: usize,
    pub lane: usize,
    pub pos: usize,
    pub micros: u64,
}

/// A declarative, seedable fault schedule. Parsed from the CLI spec
/// grammar (`;`-separated):
///
/// ```text
/// kill:<node>@<after>           node dies after completing N tasks
/// drop:<permille>               drop N‰ of planned cross-node sends
/// delay:<micros>:<permille>     delay N‰ of sends by M microseconds
/// stall:<node>.<lane>@<pos>:<micros>   stall one lane before a task
/// ```
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    pub kills: Vec<Kill>,
    /// Permille of planned cross-node sends to drop (seeded draw).
    pub drop_permille: u32,
    pub delay: Option<Delay>,
    pub stalls: Vec<Stall>,
}

impl FaultPlan {
    pub fn is_empty(&self) -> bool {
        self.kills.is_empty()
            && self.drop_permille == 0
            && self.delay.is_none()
            && self.stalls.is_empty()
    }

    /// Parse the `--chaos` spec grammar.
    pub fn parse(spec: &str) -> Result<FaultPlan, ChaosError> {
        let bad = |part: &str, why: &str| {
            ChaosError::Spec(format!("bad fault spec `{part}`: {why}"))
        };
        let int = |part: &str, s: &str| -> Result<u64, ChaosError> {
            s.trim().parse::<u64>().map_err(|_| bad(part, "expected an unsigned integer"))
        };
        let mut fp = FaultPlan::default();
        for part in spec.split(';') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (op, rest) = part
                .split_once(':')
                .ok_or_else(|| bad(part, "expected op:args"))?;
            match op.trim() {
                "kill" => {
                    let (node, after) = rest
                        .split_once('@')
                        .ok_or_else(|| bad(part, "expected kill:<node>@<after>"))?;
                    fp.kills.push(Kill {
                        node: int(part, node)? as usize,
                        after: int(part, after)? as usize,
                    });
                }
                "drop" => {
                    let p = int(part, rest)?;
                    if p > 1000 {
                        return Err(bad(part, "permille must be 0..=1000"));
                    }
                    fp.drop_permille = p as u32;
                }
                "delay" => {
                    let (us, p) = rest
                        .split_once(':')
                        .ok_or_else(|| bad(part, "expected delay:<micros>:<permille>"))?;
                    let p = int(part, p)?;
                    if p > 1000 {
                        return Err(bad(part, "permille must be 0..=1000"));
                    }
                    fp.delay = Some(Delay { micros: int(part, us)?, permille: p as u32 });
                }
                "stall" => {
                    let (place, us) = rest
                        .split_once(':')
                        .ok_or_else(|| bad(part, "expected stall:<node>.<lane>@<pos>:<micros>"))?;
                    let (node, at) = place
                        .split_once('.')
                        .ok_or_else(|| bad(part, "expected <node>.<lane>@<pos>"))?;
                    let (lane, pos) = at
                        .split_once('@')
                        .ok_or_else(|| bad(part, "expected <lane>@<pos>"))?;
                    fp.stalls.push(Stall {
                        node: int(part, node)? as usize,
                        lane: int(part, lane)? as usize,
                        pos: int(part, pos)? as usize,
                        micros: int(part, us)?,
                    });
                }
                other => return Err(bad(part, &format!("unknown op `{other}`"))),
            }
        }
        Ok(fp)
    }
}

impl fmt::Display for FaultPlan {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut parts: Vec<String> = Vec::new();
        for k in &self.kills {
            parts.push(format!("kill:{}@{}", k.node, k.after));
        }
        if self.drop_permille > 0 {
            parts.push(format!("drop:{}", self.drop_permille));
        }
        if let Some(d) = &self.delay {
            parts.push(format!("delay:{}:{}", d.micros, d.permille));
        }
        for s in &self.stalls {
            parts.push(format!("stall:{}.{}@{}:{}", s.node, s.lane, s.pos, s.micros));
        }
        write!(f, "{}", parts.join(";"))
    }
}

/// Knobs of a chaos run: the plain exec knobs, the fault schedule, and
/// the failure-detection protocol parameters.
#[derive(Clone, Debug)]
pub struct ChaosOptions {
    pub exec: ExecOptions,
    pub faults: FaultPlan,
    /// Seeds the drop/delay draws (independent of the schedule seed).
    pub fault_seed: u64,
    /// Heartbeat pump interval in microseconds.
    pub heartbeat_us: u64,
    /// Consecutive missed intervals before a node is declared dead.
    pub miss_threshold: u32,
}

impl Default for ChaosOptions {
    fn default() -> ChaosOptions {
        ChaosOptions {
            exec: ExecOptions::default(),
            faults: FaultPlan::default(),
            fault_seed: 0,
            heartbeat_us: 200,
            miss_threshold: 25,
        }
    }
}

/// Chaos-run failure.
#[derive(Clone, Debug, PartialEq)]
pub enum ChaosError {
    /// Malformed fault spec or a fault aimed outside the machine.
    Spec(String),
    Plan(PlanError),
    /// The fault plan kills every node — nothing left to recover onto.
    NoSurvivors,
}

impl From<PlanError> for ChaosError {
    fn from(e: PlanError) -> ChaosError {
        ChaosError::Plan(e)
    }
}

impl fmt::Display for ChaosError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ChaosError::Spec(s) => write!(f, "chaos spec: {s}"),
            ChaosError::Plan(e) => write!(f, "chaos plan: {e}"),
            ChaosError::NoSurvivors => write!(f, "chaos: fault plan kills every node"),
        }
    }
}

impl std::error::Error for ChaosError {}

/// Deterministic record of what was injected, detected, and replanned.
/// Contains no wall-clock quantities — for a given plan, `FaultPlan`,
/// and seed the report is identical across worker counts.
#[derive(Clone, Debug)]
pub struct ChaosReport {
    /// Canonical fault spec string.
    pub spec: String,
    pub fault_seed: u64,
    pub nodes: usize,
    /// Nodes still alive at the end of the run.
    pub survivors: usize,
    /// Killed nodes as (node, tasks it completed before dying).
    pub killed: Vec<(usize, usize)>,
    /// Heartbeat declarations as (node, missed intervals at declaration).
    pub detections: Vec<(usize, u32)>,
    /// Tasks whose execution or inputs were lost to the faults.
    pub doomed_tasks: usize,
    pub dropped_msgs: usize,
    pub delayed_msgs: usize,
    pub stalled_lanes: usize,
    /// Tasks the recovery round re-executed (doomed + lost lineage).
    pub rerun_tasks: usize,
    /// Rerun tasks that had already completed (lineage replays: no
    /// events, recomputation only).
    pub replayed_tasks: usize,
    /// Surviving tile versions re-delivered to recovery consumers.
    pub refetched_tiles: usize,
    /// Rerouted producer sends in the recovery round.
    pub recovery_sends: usize,
    /// Extra cross-node bytes the recovery moved (refetches + reroutes).
    pub recovery_inter_bytes: u64,
    /// 1 = faults absorbed without replanning, 2 = recovery round ran.
    pub rounds: usize,
    pub heartbeat_us: u64,
    pub miss_threshold: u32,
    /// Human-readable fault/recovery timeline, deterministic order.
    pub timeline: Vec<String>,
}

fn fnv(h: u64, x: u64) -> u64 {
    (h ^ x).wrapping_mul(0x0000_0100_0000_01b3)
}

fn fnv_str(mut h: u64, s: &str) -> u64 {
    for b in s.bytes() {
        h = fnv(h, b as u64);
    }
    fnv(h, 0xff)
}

impl ChaosReport {
    /// Order-sensitive digest of every deterministic field — what the
    /// determinism tests compare across worker counts.
    pub fn digest(&self) -> u64 {
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        h = fnv_str(h, &self.spec);
        for x in [
            self.fault_seed,
            self.nodes as u64,
            self.survivors as u64,
            self.doomed_tasks as u64,
            self.dropped_msgs as u64,
            self.delayed_msgs as u64,
            self.stalled_lanes as u64,
            self.rerun_tasks as u64,
            self.replayed_tasks as u64,
            self.refetched_tiles as u64,
            self.recovery_sends as u64,
            self.recovery_inter_bytes,
            self.rounds as u64,
        ] {
            h = fnv(h, x);
        }
        for (n, c) in &self.killed {
            h = fnv(fnv(h, *n as u64), *c as u64);
        }
        for (n, m) in &self.detections {
            h = fnv(fnv(h, *n as u64), *m as u64);
        }
        for line in &self.timeline {
            h = fnv_str(h, line);
        }
        h
    }

    /// JSON fault-timeline report (the CI chaos artifact).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("spec", Json::Str(self.spec.clone())),
            ("fault_seed", Json::Num(self.fault_seed as f64)),
            ("nodes", Json::Num(self.nodes as f64)),
            ("survivors", Json::Num(self.survivors as f64)),
            (
                "killed",
                Json::arr(self.killed.iter().map(|(n, c)| {
                    Json::obj(vec![
                        ("node", Json::Num(*n as f64)),
                        ("completed_before_death", Json::Num(*c as f64)),
                    ])
                })),
            ),
            (
                "detections",
                Json::arr(self.detections.iter().map(|(n, m)| {
                    Json::obj(vec![
                        ("node", Json::Num(*n as f64)),
                        ("missed_beats", Json::Num(*m as f64)),
                    ])
                })),
            ),
            ("doomed_tasks", Json::Num(self.doomed_tasks as f64)),
            ("dropped_msgs", Json::Num(self.dropped_msgs as f64)),
            ("delayed_msgs", Json::Num(self.delayed_msgs as f64)),
            ("stalled_lanes", Json::Num(self.stalled_lanes as f64)),
            ("rerun_tasks", Json::Num(self.rerun_tasks as f64)),
            ("replayed_tasks", Json::Num(self.replayed_tasks as f64)),
            ("refetched_tiles", Json::Num(self.refetched_tiles as f64)),
            ("recovery_sends", Json::Num(self.recovery_sends as f64)),
            ("recovery_inter_bytes", Json::Num(self.recovery_inter_bytes as f64)),
            ("rounds", Json::Num(self.rounds as f64)),
            ("heartbeat_us", Json::Num(self.heartbeat_us as f64)),
            ("miss_threshold", Json::Num(self.miss_threshold as f64)),
            ("digest", Json::Str(digest_hex(self.digest()))),
            (
                "timeline",
                Json::arr(self.timeline.iter().map(|l| Json::Str(l.clone()))),
            ),
        ])
    }
}

/// A chaos run's results: the (recovered) execution outcome plus the
/// deterministic fault/recovery report.
#[derive(Debug)]
pub struct ChaosOutcome {
    pub result: ExecResult,
    pub report: ChaosReport,
}

/// Execute a mapped program under a fault schedule. Mirrors
/// [`crate::exec::execute`]'s inputs; the extra knobs live in
/// [`ChaosOptions`]. The returned [`ExecResult`] satisfies the same
/// oracle contract as a fault-free run — identical placements, log
/// multiset, and (recovered) checksum.
pub fn execute_chaos(
    launches: &[IndexLaunch],
    env: &DataEnv,
    deps: &Dependences,
    run: &PipelineRun,
    desc: &MachineDesc,
    policies: &dyn MappingPolicies,
    opts: &ChaosOptions,
) -> Result<ChaosOutcome, ChaosError> {
    let t_plan = obs::now();
    let plan = plan::build(launches, env, deps, run, desc, policies, opts.exec.seed)?;
    if let Some(t0) = t_plan {
        let tasks = plan.tasks.len() as i64;
        obs::span(Cat::Compile, "plan_build", Some("chaos"), 0, 0, t0, [("tasks", tasks), ("", 0)]);
    }
    let inj = inject::plan_injection(&plan, &opts.faults, opts.fault_seed)?;
    let nodes = desc.nodes;
    let has_kills = inj.dead.iter().any(|&d| d);
    let start = Instant::now();
    let cluster = Cluster::new(nodes);

    // Round 1: run with faults injected. Survivors retain superseded
    // tile versions only when deaths are scheduled (lineage replays may
    // need the exact inputs a completed task originally saw).
    let spec1 = RoundSpec {
        lanes: inj.lanes1.clone(),
        eff_node: None,
        drops: inj.drops.clone(),
        delays: inj.delays.clone(),
        stalls: inj.stalls.clone(),
        sends: None,
        expected: inj.expected1.clone(),
        refetch: Vec::new(),
        done_seed: None,
        replay: None,
        exact: false,
        retain: has_kills.then(|| inj.dead.iter().map(|&d| !d).collect()),
    };
    let pulse = has_kills.then(|| {
        let mut lanes_per_node = vec![0usize; nodes];
        for (proc, _) in &spec1.lanes {
            lanes_per_node[proc.node] += 1;
        }
        Pulse::new(nodes, opts.heartbeat_us.max(1), inj.dead.clone(), lanes_per_node)
    });
    let planned_dead: Vec<usize> = (0..nodes).filter(|&n| inj.dead[n]).collect();
    let mut detections: Vec<(usize, u32)> = Vec::new();
    let t_round1 = obs::now();
    let round1 = std::thread::scope(|s| {
        let miss = opts.miss_threshold;
        let pd = &planned_dead;
        let monitor = pulse.as_ref().map(|p| s.spawn(move || detect::monitor(p, miss, pd)));
        let out = node::run_round(
            &cluster,
            &plan,
            &spec1,
            opts.exec.lanes,
            opts.exec.kernels,
            0,
            pulse.as_ref(),
        );
        // Detection causally gates recovery: the monitor must have
        // declared every scheduled death before replanning starts.
        if let Some(m) = monitor {
            detections = m.join().expect("chaos monitor panicked");
        }
        out
    });
    if let Some(t0) = t_round1 {
        let kills = planned_dead.len() as i64;
        let drops = inj.drops.len() as i64;
        let args = [("kills", kills), ("drops", drops)];
        obs::span(Cat::Recovery, "round", Some("inject"), 0, 0, t0, args);
    }
    let mut events = round1.events;
    let next_seq = round1.next_seq;

    // Recovery: wipe dead stores, take inventory of what survived, and
    // replan the lost suffix onto the survivors.
    let mut recovery: Option<recover::Recovery> = None;
    if has_kills || !inj.drops.is_empty() {
        for n in 0..nodes {
            if inj.dead[n] {
                cluster.stores[n].wipe();
                cluster.pools[n].clear();
            }
        }
        let inventory: Vec<HashSet<(Key, u64)>> = (0..nodes)
            .map(|n| if inj.dead[n] { HashSet::new() } else { cluster.stores[n].inventory() })
            .collect();
        let t_replan = obs::now();
        let rec = recover::plan_recovery(&plan, &inj, &inventory);
        if let Some(t0) = t_replan {
            let args = [("rerun", rec.rerun_count as i64), ("refetch", rec.refetch.len() as i64)];
            obs::span(Cat::Recovery, "replan", None, 0, 0, t0, args);
        }
        if rec.rerun_count > 0 {
            let spec2 = RoundSpec {
                lanes: rec.lanes2.clone(),
                eff_node: Some(rec.eff_node.clone()),
                drops: HashSet::new(),
                delays: HashMap::new(),
                stalls: HashMap::new(),
                sends: Some(rec.sends2.clone()),
                expected: rec.expected2.clone(),
                refetch: rec.refetch.clone(),
                done_seed: Some(inj.completed.clone()),
                replay: Some(rec.replay.clone()),
                exact: true,
                retain: Some(inj.dead.iter().map(|&d| !d).collect()),
            };
            let t_round2 = obs::now();
            let out2 = node::run_round(
                &cluster,
                &plan,
                &spec2,
                opts.exec.lanes,
                opts.exec.kernels,
                next_seq,
                None,
            );
            if let Some(t0) = t_round2 {
                let args = [("rerun", rec.rerun_count as i64), ("sends", rec.send_count as i64)];
                obs::span(Cat::Recovery, "round", Some("recover"), 0, 0, t0, args);
            }
            events.extend(out2.events);
        }
        recovery = Some(rec);
    }

    // A degraded machine is a new shape: plans compiled for the full
    // machine no longer describe it, so purge them from the shared
    // plan cache (subsequent mapping requests recompile under the
    // surviving-node MachineKey).
    let survivors = nodes - planned_dead.len();
    if has_kills {
        PlanCache::global().invalidate_machine(&desc.cache_key());
        let args = [("survivors", survivors as i64), ("nodes", nodes as i64)];
        obs::instant(Cat::Cache, "invalidate_machine", None, 0, 0, args);
        let mut degraded = desc.clone();
        degraded.nodes = survivors;
        // Touch the degraded key so the shape is canonicalized the same
        // way a fresh `plan_domain` under it would be.
        let _ = degraded.cache_key();
    }

    let recovered = recovery.as_ref().is_some_and(|r| r.rerun_count > 0);
    let alive: Vec<bool> = inj.dead.iter().map(|&d| !d).collect();
    let (checksum, peak_resident) = node::digest(&cluster, &alive);
    let wall_seconds = start.elapsed().as_secs_f64();
    // The log stays the logical schedule (events carry planned procs;
    // replays are silent), so per-proc order is the plan's own lanes
    // whenever a recovery round ran.
    let per_proc = if recovered {
        plan.lanes
            .iter()
            .map(|(p, list)| (*p, list.iter().map(|&t| plan.tasks[t].pt.clone()).collect()))
            .collect()
    } else {
        round1.per_proc
    };
    let log = assemble_log(&plan, events);

    let mut timeline = inj.timeline.clone();
    for (n, m) in &detections {
        timeline.push(format!("detect node={n} missed={m}"));
    }
    if let Some(rec) = &recovery {
        timeline.extend(rec.timeline.iter().cloned());
    }
    let report = ChaosReport {
        spec: opts.faults.to_string(),
        fault_seed: opts.fault_seed,
        nodes,
        survivors,
        killed: inj.killed.clone(),
        detections,
        doomed_tasks: inj.doomed.iter().filter(|&&d| d).count(),
        dropped_msgs: inj.drops.len(),
        delayed_msgs: inj.delays.len(),
        stalled_lanes: inj.stalls.len(),
        rerun_tasks: recovery.as_ref().map_or(0, |r| r.rerun_count),
        replayed_tasks: recovery.as_ref().map_or(0, |r| r.replay_count),
        refetched_tiles: recovery.as_ref().map_or(0, |r| r.refetch.len()),
        recovery_sends: recovery.as_ref().map_or(0, |r| r.send_count),
        recovery_inter_bytes: recovery.as_ref().map_or(0, |r| r.recovery_inter_bytes),
        rounds: if recovered { 2 } else { 1 },
        heartbeat_us: opts.heartbeat_us,
        miss_threshold: opts.miss_threshold,
        timeline,
    };
    let result = ExecResult {
        wall_seconds,
        total_flops: plan.total_flops,
        intra_bytes: plan.intra_bytes,
        inter_bytes: plan.inter_bytes,
        peak_resident,
        checksum,
        tasks: plan.tasks.len(),
        placements: plan.placements,
        log,
        per_proc,
        families: plan.families,
    };
    Ok(ChaosOutcome { result, report })
}
