//! Fault injection planning: resolve a declarative [`FaultPlan`] against
//! a concrete [`ExecPlan`] before any thread starts.
//!
//! Everything here is pure plan arithmetic, which is what makes chaos
//! runs deterministic: a kill becomes a cutoff in the plan's global
//! static order; drops and delays are seeded draws over the planned
//! sends in (task, send-position) order; and the *doomed set* — tasks
//! that cannot or must not execute in the injected round — is computed
//! by one forward pass so the runtime never needs failure-time logic.
//!
//! Doom propagates three ways: a task past its node's kill cutoff is
//! doomed; a task waiting on a doomed task is doomed (this also keeps
//! round 1 deadlock-free — no live task ever waits on a task that will
//! never run); and a task whose planned delivery of an input tile
//! version is severed (producer doomed, or the carrying send dropped)
//! is doomed. Doomed tasks are filtered out of the lane schedules
//! entirely — a kill is lane surgery, not a runtime branch.

use super::{ChaosError, FaultPlan};
use crate::exec::plan::{mix, ExecPlan, Key};
use crate::machine::topology::ProcId;
use std::collections::{HashMap, HashSet};

/// Salt separating the drop draw from the schedule seed.
const DROP_SALT: u64 = 0x4452_4f50_5f53_4544;
/// Salt separating the delay draw from the drop draw.
const DELAY_SALT: u64 = 0x4445_4c41_595f_5344;

/// How a tile version was planned to arrive at a node.
enum Deliv {
    /// Written locally by the task.
    Local(usize),
    /// Pushed by the producing task's (task, send-position) transfer.
    Remote(usize, usize),
}

/// The resolved injection: everything round 1 runs with, plus the
/// bookkeeping recovery and reporting need.
pub(crate) struct Injection {
    /// Per-node death flags.
    pub dead: Vec<bool>,
    /// Killed nodes as (node, tasks completed before death), node-sorted.
    pub killed: Vec<(usize, usize)>,
    /// Tasks that do not execute in round 1 (see module docs).
    pub doomed: Vec<bool>,
    /// `!doomed` — exactly the tasks round 1 completes.
    pub completed: Vec<bool>,
    /// The plan's lanes with doomed tasks filtered out (empty lanes
    /// dropped).
    pub lanes1: Vec<(ProcId, Vec<usize>)>,
    /// Inbound tile count per node in round 1 (doomed producers' and
    /// dropped sends excluded).
    pub expected1: Vec<usize>,
    pub drops: HashSet<(usize, usize)>,
    pub delays: HashMap<(usize, usize), u64>,
    /// Task index → stall microseconds before launch.
    pub stalls: HashMap<usize, u64>,
    /// Deterministic human-readable injection timeline.
    pub timeline: Vec<String>,
}

/// Resolve `faults` + `seed` against `plan`. Pure; deterministic.
pub(crate) fn plan_injection(
    plan: &ExecPlan,
    faults: &FaultPlan,
    seed: u64,
) -> Result<Injection, ChaosError> {
    let nodes = plan.desc.nodes;
    let ntasks = plan.tasks.len();

    // Kills → per-node cutoffs in the global static order.
    let mut dead = vec![false; nodes];
    let mut cutoff: Vec<Option<usize>> = vec![None; nodes];
    for k in &faults.kills {
        if k.node >= nodes {
            return Err(ChaosError::Spec(format!(
                "kill: node {} out of range ({} nodes)",
                k.node, nodes
            )));
        }
        dead[k.node] = true;
        cutoff[k.node] = Some(cutoff[k.node].map_or(k.after, |c| c.min(k.after)));
    }
    for s in &faults.stalls {
        if s.node >= nodes {
            return Err(ChaosError::Spec(format!(
                "stall: node {} out of range ({} nodes)",
                s.node, nodes
            )));
        }
    }
    if nodes > 0 && dead.iter().all(|&d| d) {
        return Err(ChaosError::NoSurvivors);
    }

    // A killed node completes its first `cutoff` tasks of the global
    // order; everything after is past-cutoff.
    let mut past = vec![false; ntasks];
    let mut seen = vec![0usize; nodes];
    for &t in &plan.order {
        let n = plan.tasks[t].proc.node;
        if let Some(c) = cutoff[n] {
            if seen[n] >= c {
                past[t] = true;
            }
        }
        seen[n] += 1;
    }

    // Seeded drop/delay draws over planned sends in (task, send) order.
    let mut drops: HashSet<(usize, usize)> = HashSet::new();
    let mut delays: HashMap<(usize, usize), u64> = HashMap::new();
    let mut ctr = 0u64;
    for (t, task) in plan.tasks.iter().enumerate() {
        for si in 0..task.sends.len() {
            if faults.drop_permille > 0
                && mix(seed ^ DROP_SALT, ctr) % 1000 < faults.drop_permille as u64
            {
                drops.insert((t, si));
            }
            if let Some(d) = &faults.delay {
                if d.permille > 0 && mix(seed ^ DELAY_SALT, ctr) % 1000 < d.permille as u64 {
                    delays.insert((t, si), d.micros);
                }
            }
            ctr += 1;
        }
    }

    // One forward pass in program order: track how every (tile, version)
    // was planned to reach every node, and propagate doom.
    let mut doomed = past;
    let mut delivery: HashMap<(Key, u64, usize), Deliv> = HashMap::new();
    for t in 0..ntasks {
        let task = &plan.tasks[t];
        let n = task.proc.node;
        let mut bad = doomed[t];
        if !bad {
            bad = task.waits.iter().any(|&w| doomed[w]);
        }
        if !bad {
            'reqs: for r in &task.reqs {
                for s in &r.sources {
                    let severed = match delivery.get(&(s.key.clone(), s.version, n)) {
                        Some(Deliv::Local(w)) => doomed[*w],
                        Some(Deliv::Remote(w, si)) => doomed[*w] || drops.contains(&(*w, *si)),
                        None => false,
                    };
                    if severed {
                        bad = true;
                        break 'reqs;
                    }
                }
            }
        }
        doomed[t] = bad;
        // Register what this task was planned to make available — even
        // when doomed: consumers check the producer's doom flag.
        for r in &task.reqs {
            if r.writes {
                delivery.insert(((r.region, r.rect.clone()), r.write_version, n), Deliv::Local(t));
            }
        }
        for (si, sp) in task.sends.iter().enumerate() {
            delivery.insert((sp.key.clone(), sp.version, sp.to_node), Deliv::Remote(t, si));
        }
    }
    let completed: Vec<bool> = doomed.iter().map(|&d| !d).collect();

    // Lane surgery: doomed tasks vanish from the schedules. Because
    // lanes project one global order and doom is closed under waits,
    // the filtered schedules run without any runtime failure logic.
    let lanes1: Vec<(ProcId, Vec<usize>)> = plan
        .lanes
        .iter()
        .map(|(p, list)| {
            (*p, list.iter().copied().filter(|&t| !doomed[t]).collect::<Vec<usize>>())
        })
        .filter(|(_, list)| !list.is_empty())
        .collect();

    // Round-1 inbound counts: live producers' surviving sends only.
    let mut expected1 = vec![0usize; nodes];
    for (t, task) in plan.tasks.iter().enumerate() {
        if doomed[t] {
            continue;
        }
        for (si, sp) in task.sends.iter().enumerate() {
            if !drops.contains(&(t, si)) {
                expected1[sp.to_node] += 1;
            }
        }
    }

    // Resolve lane stalls against the *post-surgery* lanes.
    let mut stalls: HashMap<usize, u64> = HashMap::new();
    let mut stall_lines: Vec<String> = Vec::new();
    for s in &faults.stalls {
        let lane = lanes1.iter().filter(|(p, _)| p.node == s.node).nth(s.lane);
        match lane.and_then(|(_, list)| list.get(s.pos)) {
            Some(&t) => {
                *stalls.entry(t).or_insert(0) += s.micros;
                stall_lines.push(format!(
                    "stall node={} lane={} pos={} task={} micros={}",
                    s.node, s.lane, s.pos, t, s.micros
                ));
            }
            None => stall_lines.push(format!(
                "stall node={} lane={} pos={} skipped (no such lane position)",
                s.node, s.lane, s.pos
            )),
        }
    }

    let killed: Vec<(usize, usize)> = (0..nodes)
        .filter(|&n| dead[n])
        .map(|n| {
            let done = (0..ntasks)
                .filter(|&t| plan.tasks[t].proc.node == n && !doomed[t])
                .count();
            (n, done)
        })
        .collect();

    // Deterministic injection timeline: kills, drops, delay summary,
    // stalls.
    let mut timeline: Vec<String> = Vec::new();
    for (n, done) in &killed {
        let c = cutoff[*n].unwrap_or(0);
        timeline.push(format!("kill node={n} after={c} completes={done}"));
    }
    let mut drop_list: Vec<(usize, usize)> = drops.iter().copied().collect();
    drop_list.sort_unstable();
    for (t, si) in &drop_list {
        let sp = &plan.tasks[*t].sends[*si];
        timeline.push(format!(
            "drop task={t} send={si} to={} bytes={}",
            sp.to_node, sp.bytes
        ));
    }
    if let Some(d) = &faults.delay {
        timeline.push(format!(
            "delay micros={} permille={} hits={}",
            d.micros,
            d.permille,
            delays.len()
        ));
    }
    timeline.extend(stall_lines);

    Ok(Injection {
        dead,
        killed,
        doomed,
        completed,
        lanes1,
        expected1,
        drops,
        delays,
        stalls,
        timeline,
    })
}
