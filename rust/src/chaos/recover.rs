//! Recovery planning: replan the lost suffix of the schedule onto the
//! survivors.
//!
//! Inputs: the original [`ExecPlan`], the resolved [`Injection`], and a
//! per-node *inventory* of exactly which (tile, version) pairs each
//! survivor can still serve (current tiles plus retained superseded
//! versions). Output: a complete recovery round — which tasks re-run
//! and where, which sends are rerouted, which surviving tiles are
//! refetched, and how many messages each node expects.
//!
//! The rerun set is the *lineage closure* of what was lost:
//!
//! 1. every doomed task re-runs;
//! 2. every tile version a rerun task consumes, and the final (latest)
//!    version of every tile — the state the checksum is taken over —
//!    must either survive on some node or have its writer re-run too;
//! 3. rule 2 applies recursively to the re-run writers' own inputs,
//!    bottoming out at deterministic cold bases.
//!
//! Rerun tasks that had already completed in round 1 are *replays*:
//! they recompute lost lineage (pure kernels make recomputation exact)
//! but emit no events and are pre-marked done, so the logical transition
//! log stays exactly the oracle's.
//!
//! Re-placement maps each dead node round-robin onto the survivors,
//! preserving processor kind and local index (machine shapes are
//! homogeneous); surviving tasks keep their planned processor. The
//! recovery schedule is the plan's global order filtered to the rerun
//! set and grouped under *effective* processors — still a projection of
//! one topological order, hence still deadlock-free.

use super::inject::Injection;
use crate::exec::node::Refetch;
use crate::exec::plan::{ExecPlan, Key, SendPlan};
use crate::machine::topology::ProcId;
use std::collections::{BTreeMap, HashMap, HashSet};

/// The planned recovery round.
pub(crate) struct Recovery {
    /// Tasks the recovery round executes (doomed + lost lineage).
    pub rerun: Vec<bool>,
    /// Rerun tasks that already completed in round 1 (silent replays).
    pub replay: Vec<bool>,
    pub rerun_count: usize,
    pub replay_count: usize,
    /// Task → node it executes on in the recovery round.
    pub eff_node: Vec<usize>,
    /// Recovery lane schedules, grouped under effective processors.
    pub lanes2: Vec<(ProcId, Vec<usize>)>,
    /// Per-task rerouted sends (replaces the plan's sends in round 2).
    pub sends2: Vec<Vec<SendPlan>>,
    /// Inbound tile count per node in round 2 (reroutes + refetches).
    pub expected2: Vec<usize>,
    /// Surviving tile versions re-delivered to where recovery needs
    /// them.
    pub refetch: Vec<Refetch>,
    pub send_count: usize,
    /// Cross-node bytes the recovery moves (reroutes + refetches).
    pub recovery_inter_bytes: u64,
    pub timeline: Vec<String>,
}

/// Plan the recovery round. Pure; deterministic given the inventory
/// (which is itself determined by the injection).
pub(crate) fn plan_recovery(
    plan: &ExecPlan,
    inj: &Injection,
    inventory: &[HashSet<(Key, u64)>],
) -> Recovery {
    let nodes = plan.desc.nodes;
    let ntasks = plan.tasks.len();

    // One plan walk: who writes every (tile, version), how big it is,
    // and the final version of every tile.
    let mut writer_of: HashMap<(Key, u64), usize> = HashMap::new();
    let mut bytes_of: HashMap<(Key, u64), u64> = HashMap::new();
    let mut latest: HashMap<Key, u64> = HashMap::new();
    for (t, task) in plan.tasks.iter().enumerate() {
        for r in &task.reqs {
            if r.writes {
                let key: Key = (r.region, r.rect.clone());
                writer_of.insert((key.clone(), r.write_version), t);
                bytes_of.insert((key.clone(), r.write_version), r.bytes);
                let e = latest.entry(key).or_insert(0);
                *e = (*e).max(r.write_version);
            }
        }
    }
    let available = |key: &Key, v: u64| inventory.iter().any(|inv| inv.contains(&(key.clone(), v)));

    // Lineage closure (module docs, rules 1–3).
    let mut rerun = inj.doomed.clone();
    let mut seen: HashSet<(Key, u64)> = HashSet::new();
    let mut worklist: Vec<(Key, u64)> = Vec::new();
    let mut need = |key: &Key, v: u64, seen: &mut HashSet<(Key, u64)>, wl: &mut Vec<(Key, u64)>| {
        if seen.insert((key.clone(), v)) {
            wl.push((key.clone(), v));
        }
    };
    for (t, task) in plan.tasks.iter().enumerate() {
        if !rerun[t] {
            continue;
        }
        for r in &task.reqs {
            for s in &r.sources {
                need(&s.key, s.version, &mut seen, &mut worklist);
            }
        }
    }
    let mut final_keys: Vec<(&Key, u64)> = latest.iter().map(|(k, &v)| (k, v)).collect();
    final_keys.sort_by(|a, b| {
        (a.0 .0, &a.0 .1.lo, &a.0 .1.hi, a.1).cmp(&(b.0 .0, &b.0 .1.lo, &b.0 .1.hi, b.1))
    });
    for (key, v) in final_keys {
        need(key, v, &mut seen, &mut worklist);
    }
    while let Some((key, v)) = worklist.pop() {
        if available(&key, v) {
            continue;
        }
        let Some(&w) = writer_of.get(&(key.clone(), v)) else {
            continue;
        };
        if rerun[w] {
            continue;
        }
        rerun[w] = true;
        for r in &plan.tasks[w].reqs {
            for s in &r.sources {
                need(&s.key, s.version, &mut seen, &mut worklist);
            }
        }
    }
    let replay: Vec<bool> = (0..ntasks).map(|t| rerun[t] && inj.completed[t]).collect();
    let rerun_count = rerun.iter().filter(|&&b| b).count();
    let replay_count = replay.iter().filter(|&&b| b).count();

    // Re-placement: dead nodes map round-robin onto survivors; kind and
    // local index are preserved (homogeneous shapes).
    let survivors: Vec<usize> = (0..nodes).filter(|&n| !inj.dead[n]).collect();
    let eff_node: Vec<usize> = (0..ntasks)
        .map(|t| {
            let n = plan.tasks[t].proc.node;
            if inj.dead[n] {
                survivors[n % survivors.len()]
            } else {
                n
            }
        })
        .collect();

    // Recovery lanes: the global order filtered to the rerun set,
    // grouped under effective processors (lanes from several dead nodes
    // may merge — the merged list is still a projection of the global
    // order).
    let mut lanes_map: BTreeMap<ProcId, Vec<usize>> = BTreeMap::new();
    for &t in &plan.order {
        if !rerun[t] {
            continue;
        }
        let p = plan.tasks[t].proc;
        let ep = ProcId { node: eff_node[t], kind: p.kind, local: p.local };
        lanes_map.entry(ep).or_default().push(t);
    }
    let lanes2: Vec<(ProcId, Vec<usize>)> = lanes_map.into_iter().collect();

    // Routing: walk rerun tasks in dependence order tracking where every
    // (tile, version) will be; sources not local to a task's effective
    // node arrive either from their re-run writer (rerouted send) or
    // from a survivor that still holds them (refetch).
    let mut avail: HashSet<(Key, u64, usize)> = HashSet::new();
    for (n, inv) in inventory.iter().enumerate() {
        for (key, v) in inv {
            avail.insert((key.clone(), *v, n));
        }
    }
    let mut sends2: Vec<Vec<SendPlan>> = vec![Vec::new(); ntasks];
    let mut expected2 = vec![0usize; nodes];
    let mut refetch: Vec<Refetch> = Vec::new();
    let mut send_count = 0usize;
    let mut inter_bytes = 0u64;
    for &t in &plan.order {
        if !rerun[t] {
            continue;
        }
        let n = eff_node[t];
        for r in &plan.tasks[t].reqs {
            for s in &r.sources {
                if avail.contains(&(s.key.clone(), s.version, n)) {
                    continue;
                }
                let kv = (s.key.clone(), s.version);
                let bytes = *bytes_of.get(&kv).unwrap_or(&0);
                match writer_of.get(&kv) {
                    Some(&w) if rerun[w] => {
                        // The writer re-runs; it was processed earlier
                        // in this walk (topological order), so if its
                        // effective node differs, reroute a send.
                        let wn = eff_node[w];
                        debug_assert_ne!(
                            wn, n,
                            "a local rerun write is already in avail by now"
                        );
                        sends2[w].push(SendPlan {
                            key: s.key.clone(),
                            version: s.version,
                            bytes,
                            to_node: n,
                        });
                        send_count += 1;
                        expected2[n] += 1;
                        inter_bytes += bytes;
                    }
                    _ => {
                        // A survivor still holds it: refetch from the
                        // lowest-numbered holder.
                        let from = (0..nodes)
                            .find(|&m| avail.contains(&(s.key.clone(), s.version, m)))
                            .expect("closure guarantees survival or a re-run writer");
                        refetch.push(Refetch {
                            key: s.key.clone(),
                            version: s.version,
                            bytes,
                            from,
                            to: n,
                        });
                        expected2[n] += 1;
                        inter_bytes += bytes;
                    }
                }
                avail.insert((s.key.clone(), s.version, n));
            }
        }
        for r in &plan.tasks[t].reqs {
            if r.writes {
                avail.insert(((r.region, r.rect.clone()), r.write_version, n));
            }
        }
    }

    let mut timeline: Vec<String> = Vec::new();
    for n in 0..nodes {
        if inj.dead[n] {
            timeline.push(format!("remap node={} -> node={}", n, survivors[n % survivors.len()]));
        }
    }
    timeline.push(format!(
        "replan reruns={} replays={} refetches={} sends={} bytes={}",
        rerun_count,
        replay_count,
        refetch.len(),
        send_count,
        inter_bytes
    ));

    Recovery {
        rerun,
        replay,
        rerun_count,
        replay_count,
        eff_node,
        lanes2,
        sends2,
        expected2,
        refetch,
        send_count,
        recovery_inter_bytes: inter_bytes,
        timeline,
    }
}
