//! Failure detection: heartbeat monitoring over the executor's bounded
//! channels.
//!
//! Every node in a chaos round with scheduled deaths runs a heartbeat
//! pump (`exec::node::pump`) that beats to every peer over the same
//! bounded channels that carry tiles; receivers stamp the shared
//! [`Pulse`] board. A killed node's pump goes silent when its truncated
//! lanes finish — that silence is the only failure signal there is,
//! exactly like a real cluster.
//!
//! The monitor runs alongside round 1 and declares a node dead after
//! `miss_threshold` consecutive heartbeat intervals without a stamp. It
//! watches the nodes the fault plan scheduled to die and returns once
//! all of them are declared — the supervisor *joins the monitor before
//! replanning*, so detection causally gates recovery. The declaration
//! record is (node, threshold): deterministic by construction, with all
//! wall-clock quantities excluded so chaos reports compare bitwise
//! across worker counts.

use crate::exec::node::Pulse;
use crate::obs::{self, Cat};
use std::sync::atomic::Ordering;
use std::time::Duration;

/// Watch the pulse board until every scheduled death is declared.
/// Returns (node, missed intervals at declaration), node-sorted.
pub(crate) fn monitor(
    pulse: &Pulse,
    miss_threshold: u32,
    planned_dead: &[usize],
) -> Vec<(usize, u32)> {
    let tick = Duration::from_micros(pulse.interval_us);
    let window_nanos = miss_threshold as u64 * pulse.interval_us * 1000;
    let mut pending: Vec<usize> = planned_dead.to_vec();
    let mut declared: Vec<(usize, u32)> = Vec::new();
    while !pending.is_empty() {
        std::thread::sleep(tick);
        let now = pulse.now_nanos();
        pending.retain(|&n| {
            let last = pulse.board[n].load(Ordering::Relaxed);
            if now.saturating_sub(last) >= window_nanos {
                let args = [("node", n as i64), ("missed", miss_threshold as i64)];
                obs::instant(Cat::Heartbeat, "death_detected", None, n as u32, 902, args);
                declared.push((n, miss_threshold));
                false
            } else {
                true
            }
        });
    }
    declared.sort_unstable();
    declared
}
