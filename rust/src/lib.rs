pub mod apps;
pub mod bench;
pub mod chaos;
pub mod decompose;
pub mod exec;
pub mod mapple;
pub mod mapper;
pub mod obs;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod tasking;
pub mod tune;
pub mod machine;
pub mod util;
pub fn smoke() -> &'static str { "mapple" }
