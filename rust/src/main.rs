//! `mapple` CLI — the leader entrypoint: compile mappers, run benchmarks
//! under a mapper on the simulated cluster, execute them for real on the
//! concurrent multi-node executor, and query the decompose solver.
//!
//! Subcommands:
//!   run        — build an app, map it (mapple | expert | heuristic |
//!                tuned | auto), simulate, and report throughput/comm/memory
//!   exec       — build an app, map it, and *execute* it on real threads
//!                (one per node + per-proc lanes), reporting measured
//!                wall-clock; always differentially verified against the
//!                sequential pipeline oracle
//!   analyze    — run an app both modelled and measured, compute the
//!                critical path through each timeline with per-family
//!                blame, and emit ranked mapping advice
//!   tune       — search the mapper space with the simulator as cost model
//!                and emit the winning mapper as .mpl source; --validate
//!                re-scores the top-N genomes with real runs and reports
//!                the sim-vs-measured rank correlation
//!   compile    — parse + compile a .mpl file and dump its directive tables
//!   decompose  — solve a processor-grid factorization for an iteration space
//!   serve      — long-running mapping service: answer plan requests over
//!                TCP from a sharded single-flight plan cache
//!   apps       — list available applications
//!
//! Examples:
//!   mapple run --app cannon --nodes 2 --mapper mapple
//!   mapple exec --app summa --nodes 2 --mapper tuned --json exec.json
//!   mapple analyze --app cannon --nodes 2 --json analyze.json
//!   mapple tune --app cannon --budget 32 --validate 5
//!   mapple serve --addr 127.0.0.1:7517 --threads 8 --cache-bytes 268435456
//!   mapple tune --app circuit --nodes 2 --budget 128 --strategy beam
//!   mapple tune --app cannon --resume tuned.mpl --out tuned2.mpl
//!   mapple compile mappers/cannon.mpl --nodes 2
//!   mapple decompose --procs 48 --ispace 1024x512x64

use mapple::apps;
use mapple::bench::Flavor;
use mapple::chaos::{ChaosOptions, FaultPlan};
use mapple::decompose::{decompose, greedy_grid, Objective};
use mapple::exec::{self, ExecOptions, KernelMode};
use mapple::machine::topology::MachineDesc;
use mapple::mapper::api::Mapper;
use mapple::mapper::MappleMapper;
use mapple::mapple::MapperSpec;
use mapple::obs::{self, chrome};
use mapple::serve::cache::PlanCache;
use mapple::serve::{serve, ServeOptions};
use mapple::tune::{tune, tune_with_ctx, validate_exec, EvalCtx, StrategyKind, TuneConfig, TuneSpec};
use mapple::util::bench::fmt_time;
use mapple::util::cli::Command;
use mapple::util::json::Json;

const APPS: &[&str] = &[
    "cannon", "summa", "pumma", "johnson", "solomonik", "cosma", "stencil", "circuit", "pennant",
];

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match argv.first().map(|s| s.as_str()) {
        Some("run") => cmd_run(&argv[1..]),
        Some("exec") => cmd_exec(&argv[1..]),
        Some("analyze") => cmd_analyze(&argv[1..]),
        Some("tune") => cmd_tune(&argv[1..]),
        Some("compile") => cmd_compile(&argv[1..]),
        Some("decompose") => cmd_decompose(&argv[1..]),
        Some("serve") => cmd_serve(&argv[1..]),
        Some("apps") => {
            println!("{}", APPS.join("\n"));
            0
        }
        _ => {
            eprintln!(
                "usage: mapple <run|exec|analyze|tune|compile|decompose|serve|apps> [--help]\n\
                 Mapple — declarative mapping for distributed heterogeneous programs."
            );
            2
        }
    };
    std::process::exit(code);
}

fn build_app(name: &str, desc: &MachineDesc, scale: i64) -> Option<apps::AppInstance> {
    let procs = desc.nodes * desc.gpus_per_node;
    Some(match name {
        "cannon" => apps::cannon(64 * scale, procs),
        "summa" => apps::summa(64 * scale, procs),
        "pumma" => apps::pumma(64 * scale, procs),
        "johnson" => apps::johnson(64 * scale, procs),
        "solomonik" => apps::solomonik(64 * scale, procs),
        "cosma" => apps::cosma(64 * scale, procs),
        "stencil" => {
            let x = 512 * scale;
            let y = 512 * scale;
            let g = decompose(procs as u64, &[x as u64, y as u64]);
            apps::stencil(&apps::StencilParams {
                x,
                y,
                gx: g.factors[0] as i64,
                gy: g.factors[1] as i64,
                halo: 1,
                steps: 4,
            })
        }
        "circuit" => apps::circuit(&apps::CircuitParams {
            pieces: procs as i64 * 2,
            nodes_per_piece: 512 * scale,
            wires_per_piece: 1024 * scale,
            pct_shared: 10,
            loops: 4,
        }),
        "pennant" => apps::pennant(&apps::PennantParams {
            chunks: procs as i64 * 2,
            zones_per_chunk: 1024 * scale,
            cycles: 4,
        }),
        _ => return None,
    })
}

/// Construct the mapper for a CLI flavor. Non-Auto flavors share
/// `bench::try_mapper_for` (one flavor-to-mapper table); `Flavor::Auto`
/// tunes against the *same* workload the command runs (scale and all) —
/// the bench-sized context would optimize size-sensitive knobs
/// (memories, backpressure) for a different problem when --scale != 1.
fn build_mapper(
    flavor: &Flavor,
    app_name: &str,
    desc: &MachineDesc,
    scale: i64,
) -> Result<Box<dyn Mapper>, String> {
    if let Flavor::Auto = flavor {
        let tune_target = build_app(app_name, desc, scale)
            .ok_or_else(|| format!("unknown app '{app_name}'"))?;
        let ctx = EvalCtx::from_parts(app_name, vec![desc.clone()], vec![tune_target]);
        let result = tune_with_ctx(&TuneConfig::quick(app_name, desc), &ctx)
            .map_err(|e| format!("autotune failed: {e}"))?;
        return Ok(Box::new(MappleMapper::new(result.best.build(desc)?)));
    }
    mapple::bench::try_mapper_for(flavor, app_name, desc)
}

fn cmd_run(argv: &[String]) -> i32 {
    let cmd = Command::new("mapple run", "map + simulate a benchmark")
        .opt("app", "application name (see `mapple apps`)", Some("cannon"))
        .opt("nodes", "cluster nodes (4 GPUs each)", Some("2"))
        .opt("mapper", "mapple | tuned | expert | heuristic | auto", Some("mapple"))
        .opt("scale", "problem-size multiplier", Some("1"))
        .opt("breakdown", "write the modelled per-task-family cost breakdown JSON here", None);
    let args = match cmd.parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let nodes = args.usize("nodes").unwrap_or(2);
    let scale = args.usize("scale").unwrap_or(1) as i64;
    let app_name = args.str("app").unwrap_or("cannon").to_string();
    let desc = MachineDesc::paper_testbed(nodes);
    let Some(app) = build_app(&app_name, &desc, scale) else {
        eprintln!("unknown app '{app_name}' — see `mapple apps`");
        return 2;
    };
    let flavor = match Flavor::parse(args.str("mapper").unwrap_or("mapple")) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let mapper = match build_mapper(&flavor, &app_name, &desc, scale) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    let bd_path = args.str("breakdown").map(|s| s.to_string());
    let (out, bd) = if bd_path.is_some() {
        match apps::run_app_breakdown(&app, mapper.as_ref(), &desc) {
            Ok((o, b)) => (o, Some(b)),
            Err(e) => {
                eprintln!("run failed: {e}");
                return 1;
            }
        }
    } else {
        match apps::run_app(&app, mapper.as_ref(), &desc) {
            Ok(o) => (o, None),
            Err(e) => {
                eprintln!("run failed: {e}");
                return 1;
            }
        }
    };
    println!(
        "{app_name} on {nodes} nodes under {}:\n  makespan {}\n  throughput/node {:.2} GFLOP/s\n  comm intra {} MiB / inter {} MiB\n  peak FBMEM {} MiB{}",
        out.mapper_name,
        fmt_time(out.sim.makespan),
        out.sim.throughput_per_node(nodes) / 1e9,
        out.sim.intra_bytes >> 20,
        out.sim.inter_bytes >> 20,
        out.sim.peak_fbmem >> 20,
        out.sim.oom.as_ref().map(|o| format!("\n  *** {o}")).unwrap_or_default(),
    );
    if let (Some(path), Some(bd)) = (bd_path.as_deref(), bd) {
        if let Err(e) = std::fs::write(path, bd.to_json().pretty()) {
            eprintln!("{path}: {e}");
            return 1;
        }
        println!("[sim breakdown written to {path}]");
    }
    0
}

fn cmd_exec(argv: &[String]) -> i32 {
    let cmd = Command::new(
        "mapple exec",
        "map + execute a benchmark for real (concurrent multi-node executor)",
    )
    .opt("app", "application name (see `mapple apps`)", Some("cannon"))
    .opt("nodes", "cluster nodes (4 GPUs each)", Some("2"))
    .opt("mapper", "mapple | tuned | expert | heuristic | auto", Some("mapple"))
    .opt("scale", "problem-size multiplier", Some("1"))
    .opt("lanes", "max concurrent kernels (0 = one lane per proc)", Some("0"))
    .opt("seed", "schedule tie-break seed", Some("0"))
    .opt("kernels", "kernel tier: fast (blocked, pooled) | naive", Some("fast"))
    .opt(
        "chaos",
        "fault spec: kill:<node>@<after>;drop:<permille>;delay:<us>:<permille>;stall:<node>.<lane>@<pos>:<us>",
        None,
    )
    .opt("chaos-seed", "fault-injection seed", Some("0"))
    .opt("json", "write the ExecResult JSON report here", None)
    .opt("trace", "write a Chrome-trace JSON of the run here (load in Perfetto)", None)
    .opt("trace-capacity", "per-thread trace ring capacity in events", Some("262144"))
    .opt("breakdown", "write the measured per-task-family cost breakdown JSON here", None);
    let args = match cmd.parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let nodes = args.usize("nodes").unwrap_or(2);
    let scale = args.usize("scale").unwrap_or(1) as i64;
    let app_name = args.str("app").unwrap_or("cannon").to_string();
    let desc = MachineDesc::paper_testbed(nodes);
    let Some(app) = build_app(&app_name, &desc, scale) else {
        eprintln!("unknown app '{app_name}' — see `mapple apps`");
        return 2;
    };
    let flavor = match Flavor::parse(args.str("mapper").unwrap_or("mapple")) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let mapper = match build_mapper(&flavor, &app_name, &desc, scale) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    let kernels = match args.str("kernels").unwrap_or("fast") {
        "fast" => KernelMode::Fast,
        "naive" => KernelMode::Naive,
        other => {
            eprintln!("bad --kernels '{other}' (expected fast | naive)");
            return 2;
        }
    };
    let opts = ExecOptions {
        lanes: args.usize("lanes").unwrap_or(0),
        seed: args.usize("seed").unwrap_or(0) as u64,
        kernels,
    };
    let trace_path = args.str("trace").map(|s| s.to_string());
    let bd_path = args.str("breakdown").map(|s| s.to_string());
    // Tracing is a global toggle, not an ExecOptions knob: the executor's
    // hot paths carry no extra parameters, and a run with tracing off
    // pays one relaxed atomic load per would-be event.
    let tracing = trace_path.is_some() || bd_path.is_some();
    if tracing {
        obs::set_ring_capacity(args.usize("trace-capacity").unwrap_or(obs::DEFAULT_RING_CAP));
        obs::start();
    }
    if let Some(spec) = args.str("chaos") {
        let faults = match FaultPlan::parse(spec) {
            Ok(f) => f,
            Err(e) => {
                eprintln!("bad --chaos spec: {e}");
                return 2;
            }
        };
        let copts = ChaosOptions {
            exec: opts,
            faults,
            fault_seed: args.usize("chaos-seed").unwrap_or(0) as u64,
            ..ChaosOptions::default()
        };
        let out = match apps::chaos_app(&app, mapper.as_ref(), &desc, &copts) {
            Ok(o) => o,
            Err(e) => {
                eprintln!("chaos exec failed: {e}");
                return 1;
            }
        };
        let r = &out.chaos.report;
        println!(
            "{app_name} on {nodes} nodes under {} with faults `{}` (recovered, oracle-verified):\n  \
             wall-clock {}  ({} tasks, {} round{})\n  \
             killed {:?}  detected {:?}  survivors {:?}\n  \
             doomed {} tasks, dropped {} msgs, delayed {} msgs, stalled {} lanes\n  \
             rerun {} ({} silent replays), refetched {} tiles, {} recovery sends ({} KiB)\n  \
             checksum {:016x} == failure-free baseline (bitwise)",
            out.mapper_name,
            r.spec,
            fmt_time(out.chaos.result.wall_seconds),
            out.chaos.result.tasks,
            r.rounds,
            if r.rounds == 1 { "" } else { "s" },
            r.killed,
            r.detections,
            r.survivors,
            r.doomed_tasks,
            r.dropped_msgs,
            r.delayed_msgs,
            r.stalled_lanes,
            r.rerun_tasks,
            r.replayed_tasks,
            r.refetched_tiles,
            r.recovery_sends,
            r.recovery_inter_bytes >> 10,
            out.chaos.result.checksum,
        );
        if let Some(path) = args.str("json") {
            let mut json = out.chaos.result.to_json(&app_name, &out.mapper_name, &desc);
            if let Json::Obj(map) = &mut json {
                map.insert("chaos".to_string(), r.to_json());
                map.insert("plan_cache".to_string(), PlanCache::global().stats().to_json());
            }
            if let Err(e) = std::fs::write(path, json.pretty()) {
                eprintln!("{path}: {e}");
                return 1;
            }
            println!("[chaos exec report written to {path}]");
        }
        if tracing {
            let r = write_obs_views(trace_path.as_deref(), bd_path.as_deref(), &out.chaos.result);
            if let Err(e) = r {
                eprintln!("{e}");
                return 1;
            }
        }
        return 0;
    }
    let out = match apps::exec_app(&app, mapper.as_ref(), &desc, &opts) {
        Ok(o) => o,
        Err(e) => {
            eprintln!("exec failed: {e}");
            return 1;
        }
    };
    // Side-by-side modelled time for the same mapping ("simulated vs
    // measured": the sim predicts the paper testbed, exec measures this
    // host). exec_app computed it from the same pipeline artifacts.
    let simulated = format!(
        "{}{}",
        fmt_time(out.sim.makespan),
        out.sim.oom.as_ref().map(|o| format!(" *** {o}")).unwrap_or_default(),
    );
    println!(
        "{app_name} on {nodes} nodes under {} (measured, oracle-verified):\n  \
         wall-clock {}  ({} tasks, {} lanes)\n  \
         simulated makespan {simulated} (paper-testbed model)\n  \
         measured throughput/node {:.3} GFLOP/s\n  \
         comm intra {} KiB / inter {} KiB\n  \
         peak resident {} KiB, checksum {:016x}",
        out.mapper_name,
        fmt_time(out.exec.wall_seconds),
        out.exec.tasks,
        if opts.lanes == 0 { "per-proc".to_string() } else { opts.lanes.to_string() },
        out.exec.throughput_per_node(nodes) / 1e9,
        out.exec.intra_bytes >> 10,
        out.exec.inter_bytes >> 10,
        out.exec.peak_resident >> 10,
        out.exec.checksum,
    );
    if let Some(path) = args.str("json") {
        let mut json = out.exec.to_json(&app_name, &out.mapper_name, &desc);
        // Every MappleMapper plans through the shared process-wide cache;
        // surface its counters next to the measured numbers.
        if let Json::Obj(map) = &mut json {
            map.insert("plan_cache".to_string(), PlanCache::global().stats().to_json());
        }
        if let Err(e) = std::fs::write(path, json.pretty()) {
            eprintln!("{path}: {e}");
            return 1;
        }
        println!("[exec report written to {path}]");
    }
    if tracing {
        if let Err(e) = write_obs_views(trace_path.as_deref(), bd_path.as_deref(), &out.exec) {
            eprintln!("{e}");
            return 1;
        }
    }
    0
}

/// Ring overflow means the views below are built from a truncated trace;
/// say so loudly (GitHub Actions renders `::warning::` as an annotation)
/// and name the fix.
fn warn_dropped(dropped: u64) {
    if dropped > 0 {
        eprintln!(
            "::warning::trace dropped {dropped} events to ring overflow — \
             derived views are incomplete; raise --trace-capacity (current: {})",
            obs::ring_capacity()
        );
    }
}

/// Drain the trace a `--trace`/`--breakdown` run collected and write the
/// requested views: the Chrome-trace timeline (Perfetto-loadable) and the
/// measured per-task-family cost breakdown.
fn write_obs_views(
    trace_path: Option<&str>,
    bd_path: Option<&str>,
    result: &exec::ExecResult,
) -> Result<(), String> {
    obs::stop();
    let tr = obs::drain();
    warn_dropped(tr.dropped);
    if let Some(path) = trace_path {
        std::fs::write(path, chrome::to_chrome(&tr).pretty())
            .map_err(|e| format!("{path}: {e}"))?;
        println!("[chrome trace written to {path} — load at https://ui.perfetto.dev]");
    }
    if let Some(path) = bd_path {
        let bd = exec::breakdown(result, &tr);
        std::fs::write(path, bd.to_json().pretty()).map_err(|e| format!("{path}: {e}"))?;
        println!("[exec breakdown written to {path}]");
    }
    Ok(())
}

/// `mapple analyze`: run one (app, mapper, shape) both modelled and
/// measured, compute the critical path through each timeline with
/// per-family blame, and print the advisor's ranked findings. The JSON
/// report carries both critical paths row-for-row plus the full advice
/// document (`mapple.advice/v1`).
fn cmd_analyze(argv: &[String]) -> i32 {
    let cmd = Command::new(
        "mapple analyze",
        "critical-path analysis + mapping advice for one app and mapper",
    )
    .opt("app", "application name (see `mapple apps`)", Some("cannon"))
    .opt("nodes", "cluster nodes (4 GPUs each)", Some("2"))
    .opt("mapper", "mapple | tuned | expert | heuristic | auto", Some("mapple"))
    .opt("scale", "problem-size multiplier", Some("1"))
    .opt("lanes", "max concurrent kernels (0 = one lane per proc)", Some("0"))
    .opt("seed", "schedule tie-break seed", Some("0"))
    .opt("kernels", "kernel tier: fast (blocked, pooled) | naive", Some("fast"))
    .opt("trace-capacity", "per-thread trace ring capacity in events", Some("262144"))
    .opt("json", "write the combined analysis JSON here", None);
    let args = match cmd.parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let nodes = args.usize("nodes").unwrap_or(2);
    let scale = args.usize("scale").unwrap_or(1) as i64;
    let app_name = args.str("app").unwrap_or("cannon").to_string();
    let desc = MachineDesc::paper_testbed(nodes);
    let Some(app) = build_app(&app_name, &desc, scale) else {
        eprintln!("unknown app '{app_name}' — see `mapple apps`");
        return 2;
    };
    let flavor = match Flavor::parse(args.str("mapper").unwrap_or("mapple")) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let mapper = match build_mapper(&flavor, &app_name, &desc, scale) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    let kernels = match args.str("kernels").unwrap_or("fast") {
        "fast" => KernelMode::Fast,
        "naive" => KernelMode::Naive,
        other => {
            eprintln!("bad --kernels '{other}' (expected fast | naive)");
            return 2;
        }
    };
    let opts = ExecOptions {
        lanes: args.usize("lanes").unwrap_or(0),
        seed: args.usize("seed").unwrap_or(0) as u64,
        kernels,
    };
    obs::set_ring_capacity(args.usize("trace-capacity").unwrap_or(obs::DEFAULT_RING_CAP));
    let out = match apps::analyze_app(&app, mapper.as_ref(), &desc, &opts) {
        Ok(o) => o,
        Err(e) => {
            eprintln!("analyze failed: {e}");
            return 1;
        }
    };
    warn_dropped(out.exec_critpath.dropped_events);

    println!(
        "{app_name} on {nodes} nodes under {} (modelled + measured, oracle-verified):\n  \
         simulated makespan {}  — critical path {} over {} tasks\n  \
         measured wall-clock {}  — critical path {} over {} tasks",
        out.mapper_name,
        fmt_time(out.sim.makespan),
        fmt_time(out.sim_critpath.length_seconds),
        out.sim_critpath.steps.len(),
        fmt_time(out.exec.wall_seconds),
        fmt_time(out.exec_critpath.length_seconds),
        out.exec_critpath.steps.len(),
    );
    for (label, cp) in [("modelled", &out.sim_critpath), ("measured", &out.exec_critpath)] {
        println!("  {label} blame (per launch family, ns on the critical path):");
        for (family, row) in &cp.blame {
            if row.total_ns() == 0.0 {
                continue;
            }
            println!(
                "    {family}: compute {} wait {} intra {} inter {} recovery {} ({} tasks)",
                row.compute_ns,
                row.wait_ns,
                row.intra_transfer_ns,
                row.inter_transfer_ns,
                row.recovery_ns,
                row.tasks,
            );
        }
    }
    if out.advice.findings.is_empty() {
        println!("  advice: nothing stands out — the mapping is balanced at this shape");
    } else {
        println!("  advice ({} findings, most severe first):", out.advice.findings.len());
        for (i, f) in out.advice.findings.iter().enumerate() {
            println!("    {}. [{}] {}", i + 1, f.kind, f.title);
            for s in &f.suggestions {
                println!("       -> {}: {}", s.knob, s.action);
            }
        }
    }

    if let Some(path) = args.str("json") {
        let report = Json::obj(vec![
            ("app", Json::Str(app_name.clone())),
            ("mapper", Json::Str(out.mapper_name.clone())),
            ("nodes", Json::Num(nodes as f64)),
            ("gpus_per_node", Json::Num(desc.gpus_per_node as f64)),
            ("simulated_makespan_seconds", Json::Num(out.sim.makespan)),
            ("measured_wall_seconds", Json::Num(out.exec.wall_seconds)),
            ("sim_critpath", out.sim_critpath.to_json()),
            ("exec_critpath", out.exec_critpath.to_json()),
            ("sim_breakdown", out.sim_breakdown.to_json()),
            ("advice", out.advice.to_json()),
        ]);
        if let Err(e) = std::fs::write(path, report.pretty()) {
            eprintln!("{path}: {e}");
            return 1;
        }
        println!("[analysis written to {path}]");
    }
    0
}

fn cmd_tune(argv: &[String]) -> i32 {
    let cmd = Command::new("mapple tune", "autotune a mapper against the simulator")
        .opt("app", "application name (see `mapple apps`)", Some("cannon"))
        .opt("nodes", "cluster nodes (4 GPUs each)", Some("2"))
        .opt("budget", "candidate evaluations", Some("96"))
        .opt("batch", "candidates per parallel round", Some("16"))
        .opt("seed", "search RNG seed", Some("40961"))
        .opt("threads", "worker threads (0 = auto)", Some("0"))
        .opt("strategy", "random | greedy | beam | beamN", Some("beam"))
        .opt("resume", "warm-start from a previously emitted .mpl", None)
        .opt("out", "write the winning mapper's .mpl here", None)
        .opt("validate", "re-score the top-N genomes with real exec runs (0 = off)", Some("0"))
        .opt("validate-json", "write the rank-correlation report JSON here", None);
    let args = match cmd.parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let app = args.str("app").unwrap_or("cannon").to_string();
    let nodes = args.usize("nodes").unwrap_or(2);
    let strategy = match StrategyKind::parse(args.str("strategy").unwrap_or("beam")) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let desc = MachineDesc::paper_testbed(nodes);
    let mut cfg = TuneConfig::quick(&app, &desc);
    cfg.budget = args.usize("budget").unwrap_or(96);
    cfg.batch = args.usize("batch").unwrap_or(16).max(1);
    cfg.seed = args.usize("seed").unwrap_or(40961) as u64;
    cfg.threads = args.usize("threads").unwrap_or(0);
    cfg.strategy = strategy;
    if let Some(path) = args.str("resume") {
        let src = match std::fs::read_to_string(path) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("{path}: {e}");
                return 1;
            }
        };
        match TuneSpec::from_mpl(&app, &src, &desc) {
            Ok(spec) => {
                println!("[resuming from {path}: {} directive edits]", spec.edits());
                cfg.resume = Some(spec);
            }
            Err(e) => {
                eprintln!("{path}: cannot resume: {e}");
                return 1;
            }
        }
    }
    let start = std::time::Instant::now();
    let result = match tune(&cfg) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("tune failed: {e}");
            return 1;
        }
    };
    println!(
        "{app} on {nodes} nodes: seed makespan {} -> tuned {} ({:.2}x) \
         after {} candidates in {:.1}s ({} directive edits)",
        fmt_time(result.seed_score),
        fmt_time(result.best_score),
        result.speedup(),
        result.evaluated,
        start.elapsed().as_secs_f64(),
        result.best.edits(),
    );
    match args.str("out") {
        Some(path) => match std::fs::write(path, &result.mpl) {
            Ok(()) => println!("[winning mapper written to {path}]"),
            Err(e) => {
                eprintln!("{path}: {e}");
                return 1;
            }
        },
        None => {
            println!("\n# ---- winning mapper ----\n{}", result.mpl);
        }
    }
    let top_n = args.usize("validate").unwrap_or(0);
    if top_n > 0 {
        let report = match validate_exec(&cfg, &result, top_n, &ExecOptions::default()) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("tune --validate failed: {e}");
                return 1;
            }
        };
        println!(
            "validation over top {} genomes: Spearman rho {:.3}, Kendall tau {:.3}, \
             {} inverted pair{}",
            report.candidates.len(),
            report.spearman,
            report.kendall,
            report.inversions.len(),
            if report.inversions.len() == 1 { "" } else { "s" },
        );
        for c in &report.candidates {
            println!(
                "  sim rank {}: simulated {} -> measured {}",
                c.rank_sim,
                fmt_time(c.sim_score),
                fmt_time(c.measured),
            );
        }
        for &(i, j) in &report.inversions {
            println!("  inversion: sim prefers rank {i} over {j}, the measurement disagrees");
        }
        if let Some(path) = args.str("validate-json") {
            if let Err(e) = std::fs::write(path, report.to_json().pretty()) {
                eprintln!("{path}: {e}");
                return 1;
            }
            println!("[validation report written to {path}]");
        }
    }
    0
}

fn cmd_compile(argv: &[String]) -> i32 {
    let cmd = Command::new("mapple compile", "compile a .mpl mapper and dump its tables")
        .opt("nodes", "cluster nodes", Some("2"));
    let args = match cmd.parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let Some(path) = args.positional.first() else {
        eprintln!("usage: mapple compile <file.mpl> [--nodes N]");
        return 2;
    };
    let src = match std::fs::read_to_string(path) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("{path}: {e}");
            return 1;
        }
    };
    let desc = MachineDesc::paper_testbed(args.usize("nodes").unwrap_or(2));
    match MapperSpec::compile(&src, &desc) {
        Ok(spec) => {
            println!("{spec:#?}");
            0
        }
        Err(e) => {
            eprintln!("compile error: {e}");
            1
        }
    }
}

fn cmd_decompose(argv: &[String]) -> i32 {
    let cmd = Command::new("mapple decompose", "solve a processor-grid factorization")
        .opt("procs", "processor count to factor", Some("8"))
        .opt("ispace", "iteration space, e.g. 1024x512", Some("1024x1024"));
    let args = match cmd.parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let procs = args.usize("procs").unwrap_or(8) as u64;
    let ispace: Vec<u64> = args
        .str("ispace")
        .unwrap_or("1024x1024")
        .split('x')
        .filter_map(|s| s.parse().ok())
        .collect();
    if ispace.is_empty() {
        eprintln!("bad --ispace");
        return 2;
    }
    let r = decompose(procs, &ispace);
    let g = greedy_grid(procs, ispace.len());
    println!(
        "iteration space {ispace:?}, {procs} processors\n  decompose: {:?} (objective {:.6}, {} candidates)\n  greedy:    {g:?} (objective {:.6})\n  AM-GM bound: {:.6}",
        r.factors,
        r.objective,
        r.candidates,
        Objective::Isotropic.eval(&g, &ispace),
        Objective::amgm_lower_bound(procs, &ispace),
    );
    0
}

fn cmd_serve(argv: &[String]) -> i32 {
    let cmd = Command::new("mapple serve", "answer plan requests from a sharded plan cache")
        .opt("addr", "listen address", Some("127.0.0.1:7517"))
        .opt("threads", "max concurrent connections", Some("8"))
        .opt("shards", "plan-cache shards", Some("16"))
        .opt("cache-bytes", "plan-cache byte budget", Some("268435456"))
        .opt("trace", "write a Chrome-trace JSON of the daemon's lifetime here", None)
        .opt("trace-capacity", "per-thread trace ring capacity in events", Some("262144"));
    let args = match cmd.parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let opts = ServeOptions {
        addr: args.str("addr").unwrap_or("127.0.0.1:7517").to_string(),
        threads: args.usize("threads").unwrap_or(8).max(1),
        shards: args.usize("shards").unwrap_or(16).max(1),
        cache_bytes: args.usize("cache-bytes").unwrap_or(256 << 20),
    };
    let trace_path = args.str("trace").map(|s| s.to_string());
    if trace_path.is_some() {
        obs::set_ring_capacity(args.usize("trace-capacity").unwrap_or(obs::DEFAULT_RING_CAP));
        obs::start();
    }
    let server = match serve(&opts) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("serve failed: {e}");
            return 1;
        }
    };
    println!(
        "mapple serve listening on {} ({} threads, {} shards, {} MiB plan cache); \
         ops: plan | batch | invalidate | stats | metrics | ping | shutdown",
        server.local_addr(),
        opts.threads,
        opts.shards,
        opts.cache_bytes >> 20,
    );
    let state = std::sync::Arc::clone(server.state());
    server.join();
    let s = state.cache().stats();
    println!(
        "mapple serve stopped: {} hits / {} misses ({} coalesced, {} compiles), \
         {} evictions, {} entries resident",
        s.hits, s.misses, s.coalesced, s.compiles, s.evictions, s.entries,
    );
    if let Some(path) = trace_path.as_deref() {
        obs::stop();
        let tr = obs::drain();
        warn_dropped(tr.dropped);
        if let Err(e) = std::fs::write(path, chrome::to_chrome(&tr).pretty()) {
            eprintln!("{path}: {e}");
            return 1;
        }
        println!("[chrome trace written to {path} — load at https://ui.perfetto.dev]");
    }
    0
}
