//! Chaos differential suite: fault injection, heartbeat detection, and
//! replan-the-suffix recovery against the failure-free oracle.
//!
//! The contract under test (ISSUE 8 / ARCHITECTURE.md "Chaos and
//! recovery"): for every app, machine shape, and kernel tier, a run with
//! faults injected — a mid-run node kill, a message-drop burst, a delay
//! storm — must end with a checksum **bitwise equal** to the failure-free
//! run's, while still satisfying `ExecResult::verify_against` (identical
//! placements and transition multiset). On top of that: the failure
//! timeline, recovery schedule, and chaos-report digest are deterministic
//! in (FaultPlan, seed) across worker counts, and an empty fault plan is
//! indistinguishable from a plain run on every deterministic field.
//!
//! `mapple::apps::chaos_app` already enforces baseline-vs-recovered
//! checksum equality and both oracle verifications internally — an `Ok`
//! from it IS the recovery proof; the assertions here pin down the
//! report's shape on top.

mod common;

use common::build_app;
use mapple::apps::{chaos_app, exec_app, ChaosAppOutcome};
use mapple::bench::{mapper_for, Flavor};
use mapple::chaos::{ChaosOptions, FaultPlan};
use mapple::exec::{ExecOptions, KernelMode};
use mapple::machine::topology::MachineDesc;

const APPS: &[&str] = &[
    "cannon", "summa", "pumma", "johnson", "solomonik", "cosma", "stencil", "circuit", "pennant",
];

fn shape(nodes: usize, gpus: usize) -> MachineDesc {
    let mut d = MachineDesc::paper_testbed(nodes);
    d.gpus_per_node = gpus;
    d
}

/// Two multi-node shapes (chaos needs somewhere to recover onto).
fn chaos_shapes() -> Vec<MachineDesc> {
    vec![shape(2, 2), shape(2, 4)]
}

/// Fast-protocol chaos options so kill detection windows stay in the
/// low milliseconds (window = heartbeat_us × miss_threshold = 2ms).
fn copts(faults: FaultPlan, kernels: KernelMode, lanes: usize) -> ChaosOptions {
    ChaosOptions {
        exec: ExecOptions { lanes, kernels, ..ExecOptions::default() },
        faults,
        fault_seed: 7,
        heartbeat_us: 200,
        miss_threshold: 10,
    }
}

fn run_chaos(app_name: &str, desc: &MachineDesc, opts: &ChaosOptions) -> ChaosAppOutcome {
    let procs = desc.nodes * desc.gpus_per_node;
    let app = build_app(app_name, procs);
    let mapper = mapper_for(&Flavor::Mapple, app_name, desc);
    chaos_app(&app, mapper.as_ref(), desc, opts).unwrap_or_else(|e| {
        panic!(
            "{app_name} ({}n×{}g, {:?}, `{}`): {e}",
            desc.nodes, desc.gpus_per_node, opts.exec.kernels, opts.faults
        )
    })
}

#[test]
fn all_nine_apps_recover_bitwise_from_kill_drop_and_delay() {
    // spec × shape × kernel tier × app. chaos_app's Ok proves the
    // recovered checksum equals the failure-free oracle bitwise and that
    // both runs pass verify_against.
    let specs = ["kill:1@2", "drop:400", "delay:200:500"];
    let mut dropped_total = 0usize;
    let mut delayed_total = 0usize;
    for desc in chaos_shapes() {
        for kernels in [KernelMode::Fast, KernelMode::Naive] {
            for spec in specs {
                let faults = FaultPlan::parse(spec).unwrap();
                for app_name in APPS {
                    let out = run_chaos(app_name, &desc, &copts(faults.clone(), kernels, 0));
                    let r = &out.chaos.report;
                    let ctx = format!(
                        "{app_name} ({}n×{}g, {kernels:?}, `{spec}`)",
                        desc.nodes, desc.gpus_per_node
                    );
                    assert_eq!(r.spec, spec, "{ctx}: canonical spec");
                    match spec {
                        "kill:1@2" => {
                            assert_eq!(r.killed.len(), 1, "{ctx}");
                            assert_eq!(r.killed[0].0, 1, "{ctx}: node 1 dies");
                            assert!(r.killed[0].1 <= 2, "{ctx}: at most 2 completions");
                            // Heartbeat detection declared the death, and
                            // did so before recovery planning began.
                            assert_eq!(r.detections, vec![(1, 10)], "{ctx}");
                            assert_eq!(r.survivors, desc.nodes - 1, "{ctx}");
                            assert!(r.doomed_tasks > 0, "{ctx}: suffix was lost");
                            assert_eq!(r.rounds, 2, "{ctx}: recovery round ran");
                            assert!(r.rerun_tasks >= r.doomed_tasks, "{ctx}: lineage closure");
                        }
                        "drop:400" => {
                            assert!(r.detections.is_empty(), "{ctx}: nothing dies");
                            assert_eq!(r.survivors, desc.nodes, "{ctx}");
                            dropped_total += r.dropped_msgs;
                            if r.dropped_msgs > 0 {
                                assert!(r.doomed_tasks > 0, "{ctx}: lost deliveries doom readers");
                                assert_eq!(r.rounds, 2, "{ctx}");
                            } else {
                                assert_eq!(r.rounds, 1, "{ctx}");
                            }
                        }
                        "delay:200:500" => {
                            // A delay storm reorders, never loses: no
                            // dooming, no recovery round.
                            delayed_total += r.delayed_msgs;
                            assert_eq!(r.doomed_tasks, 0, "{ctx}");
                            assert_eq!(r.rounds, 1, "{ctx}");
                            assert_eq!(r.rerun_tasks, 0, "{ctx}");
                        }
                        _ => unreachable!(),
                    }
                }
            }
        }
    }
    // The seeded draws must actually fire somewhere across the sweep.
    assert!(dropped_total > 0, "drop:400 never dropped a message");
    assert!(delayed_total > 0, "delay:200:500 never delayed a message");
}

#[test]
fn fault_timeline_and_recovery_are_deterministic_across_worker_counts() {
    // Same FaultPlan + seed ⇒ identical failure timeline, recovery
    // schedule, and checksum whether the executor runs 1, 2, or 16
    // lanes per processor.
    let desc = shape(2, 2);
    let faults = FaultPlan::parse("kill:1@2;drop:100;delay:100:200").unwrap();
    for app_name in ["cannon", "stencil", "pennant"] {
        let baseline = run_chaos(app_name, &desc, &copts(faults.clone(), KernelMode::Fast, 1));
        let b = &baseline.chaos;
        // Repeatability at fixed lanes first.
        let again = run_chaos(app_name, &desc, &copts(faults.clone(), KernelMode::Fast, 1));
        assert_eq!(again.chaos.report.digest(), b.report.digest(), "{app_name} rerun");
        assert_eq!(again.chaos.result.checksum, b.result.checksum, "{app_name} rerun");
        for lanes in [2usize, 16] {
            let out = run_chaos(app_name, &desc, &copts(faults.clone(), KernelMode::Fast, lanes));
            let c = &out.chaos;
            assert_eq!(c.result.checksum, b.result.checksum, "{app_name} lanes={lanes}");
            assert_eq!(c.result.placements, b.result.placements, "{app_name} lanes={lanes}");
            assert_eq!(
                c.result.canonical_log(),
                b.result.canonical_log(),
                "{app_name} lanes={lanes}"
            );
            assert_eq!(c.result.per_proc, b.result.per_proc, "{app_name} lanes={lanes}");
            // The whole deterministic report — killed/detections/doomed/
            // rerun/refetch/sends/timeline — folds into one digest.
            assert_eq!(c.report.digest(), b.report.digest(), "{app_name} lanes={lanes}");
            assert_eq!(c.report.timeline, b.report.timeline, "{app_name} lanes={lanes}");
        }
    }
}

#[test]
fn empty_fault_plan_matches_a_plain_run_on_every_deterministic_field() {
    let desc = shape(2, 2);
    for app_name in ["summa", "circuit"] {
        let procs = desc.nodes * desc.gpus_per_node;
        let app = build_app(app_name, procs);
        let mapper = mapper_for(&Flavor::Mapple, app_name, &desc);
        let plain = exec_app(&app, mapper.as_ref(), &desc, &ExecOptions::default())
            .unwrap_or_else(|e| panic!("{app_name} plain: {e}"));
        let calm = chaos_app(&app, mapper.as_ref(), &desc, &ChaosOptions::default())
            .unwrap_or_else(|e| panic!("{app_name} chaos: {e}"));
        let (p, c) = (&plain.exec, &calm.chaos.result);
        assert_eq!(c.checksum, p.checksum, "{app_name}");
        assert_eq!(c.total_flops, p.total_flops, "{app_name}");
        assert_eq!(c.intra_bytes, p.intra_bytes, "{app_name}");
        assert_eq!(c.inter_bytes, p.inter_bytes, "{app_name}");
        assert_eq!(c.tasks, p.tasks, "{app_name}");
        assert_eq!(c.placements, p.placements, "{app_name}");
        assert_eq!(c.canonical_log(), p.canonical_log(), "{app_name}");
        assert_eq!(c.per_proc, p.per_proc, "{app_name}");
        // (wall_seconds and peak_resident are schedule/timing dependent
        // and deliberately not compared.)
        let r = &calm.chaos.report;
        assert!(r.spec.is_empty(), "{app_name}: canonical empty spec");
        assert_eq!(r.rounds, 1, "{app_name}");
        assert_eq!(r.doomed_tasks + r.rerun_tasks + r.refetched_tiles, 0, "{app_name}");
        assert!(r.detections.is_empty() && r.killed.is_empty(), "{app_name}");
    }
}

#[test]
fn delays_and_stalls_never_trigger_recovery() {
    // Timing-only faults perturb the physical schedule but lose nothing,
    // so the run must absorb them in round 1 — and still checksum-match
    // the oracle (enforced inside chaos_app).
    let desc = shape(2, 2);
    let faults = FaultPlan::parse("delay:200:500;stall:0.0@1:300").unwrap();
    for app_name in ["cannon", "pennant"] {
        let out = run_chaos(app_name, &desc, &copts(faults.clone(), KernelMode::Fast, 0));
        let r = &out.chaos.report;
        assert_eq!(r.rounds, 1, "{app_name}");
        assert_eq!(r.rerun_tasks, 0, "{app_name}");
        assert_eq!(r.doomed_tasks, 0, "{app_name}");
        assert!(r.stalled_lanes <= 1, "{app_name}");
    }
}

#[test]
fn fault_spec_grammar_parses_and_roundtrips() {
    let fp = FaultPlan::parse("kill:1@2; drop:400 ;delay:200:500;stall:0.1@3:50").unwrap();
    assert_eq!(fp.kills.len(), 1);
    assert_eq!((fp.kills[0].node, fp.kills[0].after), (1, 2));
    assert_eq!(fp.drop_permille, 400);
    let d = fp.delay.as_ref().unwrap();
    assert_eq!((d.micros, d.permille), (200, 500));
    assert_eq!(fp.stalls.len(), 1);
    // Display produces the canonical form; parse(display) is identity.
    let canon = fp.to_string();
    assert_eq!(canon, "kill:1@2;drop:400;delay:200:500;stall:0.1@3:50");
    assert_eq!(FaultPlan::parse(&canon).unwrap(), fp);
    // Empty and whitespace-only specs are the empty plan.
    assert!(FaultPlan::parse("").unwrap().is_empty());
    assert!(FaultPlan::parse(" ; ").unwrap().is_empty());

    for bad in [
        "explode:3",
        "kill:1",
        "kill:x@2",
        "drop:1001",
        "delay:200",
        "delay:200:2000",
        "stall:0@1:50",
        "nonsense",
    ] {
        assert!(FaultPlan::parse(bad).is_err(), "`{bad}` should not parse");
    }
}

#[test]
fn impossible_fault_plans_are_rejected_not_executed() {
    let desc = shape(2, 2);
    let app = build_app("cannon", 4);
    let mapper = mapper_for(&Flavor::Mapple, "cannon", &desc);

    // Killing every node leaves nothing to recover onto.
    let all_dead = FaultPlan::parse("kill:0@0;kill:1@0").unwrap();
    let e = chaos_app(&app, mapper.as_ref(), &desc, &copts(all_dead, KernelMode::Fast, 0))
        .unwrap_err();
    assert!(e.contains("kills every node"), "{e}");

    // A kill aimed outside the machine is a spec error.
    let out_of_range = FaultPlan::parse("kill:7@1").unwrap();
    let e = chaos_app(&app, mapper.as_ref(), &desc, &copts(out_of_range, KernelMode::Fast, 0))
        .unwrap_err();
    assert!(e.contains("chaos spec"), "{e}");
}
