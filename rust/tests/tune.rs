//! Integration tests for the simulator-guided autotuner (`mapple::tune`):
//! seeded determinism, the never-worse-than-seed property, and the
//! emitted-`.mpl` roundtrip behind `Flavor::Auto`.

use mapple::machine::point::{Rect, Tuple};
use mapple::machine::topology::MachineDesc;
use mapple::tune::{tune, StrategyKind, TuneConfig, TuneSpec};

fn small_cfg(app: &str, seed: u64, strategy: StrategyKind) -> TuneConfig {
    let mut cfg = TuneConfig::quick(app, &MachineDesc::paper_testbed(1));
    cfg.seed = seed;
    cfg.budget = 12;
    cfg.batch = 4;
    cfg.strategy = strategy;
    cfg
}

#[test]
fn same_seed_same_winner() {
    let cfg = small_cfg("cannon", 77, StrategyKind::Beam(2));
    let a = tune(&cfg).unwrap();
    let b = tune(&cfg).unwrap();
    assert_eq!(a.best, b.best, "winning genome must be deterministic in the seed");
    assert!(
        a.best_score.to_bits() == b.best_score.to_bits(),
        "{} vs {}",
        a.best_score,
        b.best_score
    );
    assert_eq!(a.mpl, b.mpl);
    assert_eq!(a.evaluated, b.evaluated);
}

#[test]
fn thread_count_does_not_change_the_winner() {
    let mut one = small_cfg("pennant", 5, StrategyKind::Beam(2));
    one.threads = 1;
    let mut four = small_cfg("pennant", 5, StrategyKind::Beam(2));
    four.threads = 4;
    let a = tune(&one).unwrap();
    let b = tune(&four).unwrap();
    assert_eq!(a.best, b.best, "parallel evaluation must not perturb the search");
    assert_eq!(a.best_score.to_bits(), b.best_score.to_bits());
}

#[test]
fn different_seeds_may_differ_but_both_improve_or_hold() {
    for (app, strategy) in [
        ("cannon", StrategyKind::Random),
        ("circuit", StrategyKind::Beam(2)),
        ("pennant", StrategyKind::Beam(1)), // greedy
    ] {
        for seed in [1u64, 2] {
            let r = tune(&small_cfg(app, seed, strategy)).unwrap();
            assert!(
                r.best_score <= r.seed_score,
                "{app}/seed{seed}: best {} worse than seed {}",
                r.best_score,
                r.seed_score
            );
            assert!(r.speedup() >= 1.0, "{app}/seed{seed}: {}", r.speedup());
            assert!(r.seed_score.is_finite() && r.best_score.is_finite());
            assert_eq!(r.evaluated, 12, "{app}/seed{seed}: budget respected");
        }
    }
}

#[test]
fn emitted_mpl_recompiles_to_equivalent_spec() {
    // The Flavor::Auto roundtrip: the winning genome's pretty-printed
    // .mpl source, recompiled with the genome's objective, reproduces the
    // built spec — identical directive tables and identical placements.
    use mapple::mapple::MapperSpec;
    let desc = MachineDesc::paper_testbed(1);
    for (app, seed) in [("circuit", 3u64), ("cannon", 9), ("pennant", 13)] {
        let r = tune(&small_cfg(app, seed, StrategyKind::Beam(2))).unwrap();
        let built = r.best.build(&desc).unwrap();
        let reparsed = MapperSpec::compile_with(&r.mpl, &desc, r.objective.clone())
            .unwrap_or_else(|e| {
                panic!("{app}: emitted mapper failed to recompile: {e}\n{}", r.mpl)
            });
        assert_eq!(built.index_task_maps, reparsed.index_task_maps, "{app}");
        assert_eq!(built.task_maps, reparsed.task_maps, "{app}");
        assert_eq!(built.regions, reparsed.regions, "{app}");
        assert_eq!(built.gc, reparsed.gc, "{app}");
        assert_eq!(built.backpressure, reparsed.backpressure, "{app}");
        // placements agree on the app's launch arities
        let domains: &[Tuple] = if app == "cannon" {
            &[Tuple::from([4, 4]), Tuple::from([2, 2])]
        } else {
            &[Tuple::from([8]), Tuple::from([5])]
        };
        for ispace in domains {
            let dom = Rect::from_extent(ispace);
            assert_eq!(
                built.plan_domain("sometask_0", &dom).unwrap(),
                reparsed.plan_domain("sometask_0", &dom).unwrap(),
                "{app} {ispace:?}"
            );
        }
    }
}

#[test]
fn resume_warm_starts_from_the_emitted_mpl() {
    // `tune --resume file.mpl`: the emitted winner reconstructs into the
    // identical genome, and a resumed run can never end up worse than
    // the run it resumed from (the warm start is scored and kept).
    let desc = MachineDesc::paper_testbed(1);
    let first = tune(&small_cfg("cannon", 77, StrategyKind::Beam(2))).unwrap();
    let resumed_genome = TuneSpec::from_mpl("cannon", &first.mpl, &desc)
        .unwrap_or_else(|e| panic!("{e}\n{}", first.mpl));
    assert_eq!(resumed_genome, first.best, "emitted .mpl reconstructs the winning genome");

    let mut cfg = small_cfg("cannon", 5, StrategyKind::Beam(2));
    cfg.budget = 4;
    cfg.resume = Some(resumed_genome);
    let second = tune(&cfg).unwrap();
    assert!(
        second.best_score <= first.best_score,
        "resumed run lost ground: {} vs {}",
        second.best_score,
        first.best_score
    );
    assert!(second.evaluated >= 1, "the warm start counts as an evaluation");
}

#[test]
fn resume_rejects_mismatched_app() {
    let desc = MachineDesc::paper_testbed(1);
    let first = tune(&small_cfg("cannon", 77, StrategyKind::Beam(2))).unwrap();
    let genome = TuneSpec::from_mpl("cannon", &first.mpl, &desc).unwrap();
    let mut cfg = small_cfg("pennant", 5, StrategyKind::Beam(2));
    cfg.resume = Some(genome);
    let e = tune(&cfg).unwrap_err();
    assert!(e.contains("resume"), "{e}");
}

#[test]
fn winner_beats_or_matches_seed_under_fresh_simulation() {
    // Re-simulate the winner outside the tuner: the reported score is a
    // real makespan, not a search artifact.
    use mapple::apps::run_app;
    use mapple::bench::build_bench_app;
    use mapple::mapper::MappleMapper;
    let desc = MachineDesc::paper_testbed(1);
    let r = tune(&small_cfg("circuit", 21, StrategyKind::Beam(2))).unwrap();
    let app = build_bench_app("circuit", &desc);
    let auto_mapper = MappleMapper::new(r.best.build(&desc).unwrap());
    let auto = run_app(&app, &auto_mapper, &desc).unwrap();
    assert!(auto.sim.oom.is_none());
    let rel = (auto.sim.makespan - r.best_score).abs() / r.best_score;
    assert!(rel < 1e-9, "reported {} vs re-simulated {}", r.best_score, auto.sim.makespan);
}
