//! Analysis suite: the critical-path analyzer, the mapping advisor, and
//! the tune-validation rank correlations (ISSUE 10 / ARCHITECTURE.md
//! "Analysis & advice").
//!
//! The contracts under test:
//! - the sim-side critical path's length is **bitwise** the simulated
//!   makespan (same fold, same floats), for all nine apps;
//! - the exec-side critical path never exceeds the measured wall clock,
//!   and its blame rows reconcile: `Σ blame + unattributed = wall×1e9`
//!   exactly, with `unattributed ≥ 0`;
//! - sim and exec blame tables share row keys, so the two views diff
//!   row-for-row like the cost breakdowns;
//! - the advice report is bitwise deterministic across exec worker
//!   counts and trace-ring capacities (it is a pure function of the
//!   mapping and shape);
//! - `validate_ranking` is bitwise repeatable under a deterministic
//!   measurement, and a fixed-seed tune run reproduces its ranked list.

mod common;

use common::build_app;
use mapple::apps::analyze_app;
use mapple::bench::{mapper_for, Flavor};
use mapple::exec::ExecOptions;
use mapple::machine::topology::MachineDesc;
use mapple::obs;
use mapple::tune::{tune, validate_ranking, TuneConfig};
use std::sync::Mutex;

const APPS: &[&str] = &[
    "cannon", "summa", "pumma", "johnson", "solomonik", "cosma", "stencil", "circuit", "pennant",
];

/// The obs collector is process-global; analyze_app toggles it, so
/// tests serialize (same discipline as `tests/obs.rs`).
static OBS_LOCK: Mutex<()> = Mutex::new(());

fn shape(nodes: usize, gpus: usize) -> MachineDesc {
    let mut d = MachineDesc::paper_testbed(nodes);
    d.gpus_per_node = gpus;
    d
}

#[test]
fn sim_critpath_length_is_bitwise_the_makespan_for_all_nine_apps() {
    let _g = OBS_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let desc = shape(2, 2);
    for app_name in APPS {
        let app = build_app(app_name, 4);
        let mapper = mapper_for(&Flavor::Mapple, app_name, &desc);
        let out = analyze_app(&app, mapper.as_ref(), &desc, &ExecOptions::default())
            .unwrap_or_else(|e| panic!("{app_name}: {e}"));
        let cp = &out.sim_critpath;
        assert_eq!(
            cp.length_seconds.to_bits(),
            out.sim.makespan.to_bits(),
            "{app_name}: sim critical path length must be bitwise the makespan"
        );
        assert_eq!(cp.length_seconds.to_bits(), cp.wall_seconds.to_bits(), "{app_name}");
        assert!(!cp.steps.is_empty(), "{app_name}: the chain reaches back to t=0");
        // The chain is ordered and ends at the makespan.
        assert!(cp.steps.windows(2).all(|w| w[0].end_ns <= w[1].end_ns), "{app_name}");
        let last = cp.steps.last().unwrap();
        assert_eq!(last.end_ns.to_bits(), (out.sim.makespan * 1e9).to_bits(), "{app_name}");
        // Sim blame telescopes to the whole modelled run: unattributed
        // is float rounding only (≤ 1 µs on millisecond-scale runs).
        let wall_ns = out.sim.makespan * 1e9;
        assert!(
            (cp.blame_total_ns() - wall_ns).abs() <= wall_ns * 1e-6 + 1e3,
            "{app_name}: sim blame {} vs makespan {} ns",
            cp.blame_total_ns(),
            wall_ns
        );
        assert_eq!(cp.dropped_events, 0, "{app_name}: the model drops nothing");
    }
}

#[test]
fn exec_critpath_respects_wall_clock_and_blame_reconciles() {
    let _g = OBS_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let desc = shape(2, 2);
    for app_name in APPS {
        let app = build_app(app_name, 4);
        let mapper = mapper_for(&Flavor::Mapple, app_name, &desc);
        let out = analyze_app(&app, mapper.as_ref(), &desc, &ExecOptions::default())
            .unwrap_or_else(|e| panic!("{app_name}: {e}"));
        let cp = &out.exec_critpath;
        assert_eq!(cp.dropped_events, 0, "{app_name}: default ring holds a 4-proc run");
        assert!(!cp.steps.is_empty(), "{app_name}: kernel spans reached the trace");
        // The measured chain fits inside the measured run.
        assert!(
            cp.length_seconds <= cp.wall_seconds,
            "{app_name}: chain {}s exceeds wall {}s",
            cp.length_seconds,
            cp.wall_seconds
        );
        assert_eq!(cp.wall_seconds.to_bits(), out.exec.wall_seconds.to_bits(), "{app_name}");
        // Accounting rule: blame + unattributed reconciles to wall clock
        // exactly (by construction), and nothing is over-attributed.
        let wall_ns = cp.wall_seconds * 1e9;
        assert_eq!(
            (wall_ns - cp.blame_total_ns()).to_bits(),
            cp.unattributed_ns.to_bits(),
            "{app_name}: unattributed is the exact remainder"
        );
        assert!(cp.unattributed_ns >= 0.0, "{app_name}: blame never exceeds wall clock");
        // Sim and exec blame tables diff row-for-row.
        assert_eq!(
            out.sim_critpath.row_keys(),
            cp.row_keys(),
            "{app_name}: sim and exec share blame row keys"
        );
        // Compute showed up on the path, and every step names a family
        // that owns a blame row.
        assert!(cp.blame.values().any(|r| r.compute_ns > 0.0), "{app_name}");
        for s in &cp.steps {
            assert!(cp.blame.contains_key(&s.family), "{app_name}: step family {}", s.family);
        }
    }
}

#[test]
fn advice_is_deterministic_across_worker_counts_and_trace_capacity() {
    let _g = OBS_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let desc = shape(2, 2);
    let app = build_app("summa", 4);
    let mapper = mapper_for(&Flavor::Mapple, "summa", &desc);

    let advice_with = |lanes: usize, ring_cap: usize| {
        obs::set_ring_capacity(ring_cap);
        let opts = ExecOptions { lanes, ..ExecOptions::default() };
        let out = analyze_app(&app, mapper.as_ref(), &desc, &opts).unwrap();
        out.advice.to_json().pretty()
    };

    let baseline = advice_with(0, obs::DEFAULT_RING_CAP);
    let serial = advice_with(1, obs::DEFAULT_RING_CAP);
    let tiny_ring = advice_with(0, 2048);
    obs::set_ring_capacity(obs::DEFAULT_RING_CAP);

    assert_eq!(baseline, serial, "advice must not depend on exec worker count");
    assert_eq!(baseline, tiny_ring, "advice must not depend on trace capacity");
    assert!(baseline.contains("mapple.advice/v1"), "schema stamp present");
    assert!(baseline.contains("suggestions"), "findings carry suggestions");
}

#[test]
fn validate_ranking_is_bitwise_repeatable_and_tune_ranked_is_reproducible() {
    // A fixed-seed tune run reproduces its ranked list…
    let desc = shape(2, 2);
    let mut cfg = TuneConfig::quick("cannon", &desc);
    cfg.budget = 8;
    cfg.batch = 4;
    let a = tune(&cfg).unwrap();
    let b = tune(&cfg).unwrap();
    assert!(a.ranked.len() >= 2, "a quick tune produces at least seed + one candidate");
    assert_eq!(a.ranked.len(), b.ranked.len());
    for ((sa, va), (sb, vb)) in a.ranked.iter().zip(&b.ranked) {
        assert_eq!(va.to_bits(), vb.to_bits(), "ranked scores are bitwise reproducible");
        assert_eq!(sa.to_mpl().unwrap(), sb.to_mpl().unwrap(), "ranked genomes agree");
    }
    // …the list is sorted by simulated score ascending…
    assert!(a.ranked.windows(2).all(|w| w[0].1 <= w[1].1), "ranked ascends");
    assert_eq!(a.ranked[0].1.to_bits(), a.best_score.to_bits(), "head is the winner");

    // …and validation against a deterministic pseudo-measurement is
    // bitwise repeatable (what "deterministic under a fixed seed" means
    // once the measurement itself is pinned).
    let measure = |specs: &[(mapple::tune::TuneSpec, f64)]| {
        let mut i = 0usize;
        let n = specs.len();
        validate_ranking("cannon", specs, n, move |_| {
            i += 1;
            // A fixed permutation of the sim order: worst first, then
            // the rest in order — guaranteed inversions, fixed ranks.
            Ok(if i == 1 { n as f64 + 1.0 } else { i as f64 })
        })
        .unwrap()
    };
    let r1 = measure(&a.ranked);
    let r2 = measure(&b.ranked);
    assert_eq!(r1.to_json().pretty(), r2.to_json().pretty(), "reports are bitwise equal");
    assert!(!r1.inversions.is_empty(), "the permuted measurement shows inversions");
    assert!(r1.spearman < 1.0 && r1.kendall < 1.0);
    for (i, j) in &r1.inversions {
        assert!(i < j, "inversions are (i, j) sim-rank pairs with i < j");
    }
}
