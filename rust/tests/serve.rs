//! Integration stress for the sharded single-flight plan cache behind
//! `mapple serve` (and every `MappleMapper`): all nine apps × both
//! spec-backed flavors hammered from many threads with mixed launch
//! shapes and a mid-run machine invalidation, verified against plans
//! computed cold (straight `MapperSpec::plan_domain`, no cache). A
//! separate run without invalidation proves the single-flight accounting
//! identity: every distinct key compiled exactly once, no matter how
//! many threads raced for it.

mod common;

use common::build_app;
use mapple::apps::mappers;
use mapple::machine::point::Tuple;
use mapple::machine::topology::MachineDesc;
use mapple::mapper::MappleMapper;
use mapple::mapple::{MapperSpec, PlacementTable};
use mapple::serve::cache::PlanCache;
use mapple::util::prng::Rng;
use std::collections::HashSet;
use std::sync::Arc;

const APPS: &[&str] = &[
    "cannon", "summa", "pumma", "johnson", "solomonik", "cosma", "stencil", "circuit", "pennant",
];

/// One cacheable request: which mapper, which launch shape.
struct Work {
    mapper: usize,
    task: String,
    ispace: Tuple,
}

/// All 18 mappers (nine apps × {base, tuned}) sharing `cache`, their
/// zero-based launch shapes, and each shape's cold-computed table.
fn fixture(
    cache: &Arc<PlanCache>,
    desc: &MachineDesc,
) -> (Vec<MappleMapper>, Vec<Work>, Vec<PlacementTable>) {
    let procs = desc.nodes * desc.gpus_per_node;
    let mut mappers_out = Vec::new();
    let mut work = Vec::new();
    let mut cold = Vec::new();
    for app_name in APPS {
        let sources =
            [mappers::mapple_source(app_name).unwrap(), mappers::tuned_source(app_name).unwrap()];
        for src in sources {
            let spec = MapperSpec::compile(src, desc).unwrap();
            let app = build_app(app_name, procs);
            let mut seen = HashSet::new();
            let mapper_idx = mappers_out.len();
            for launch in &app.launches {
                if launch.domain.lo != Tuple::zeros(launch.domain.dim()) {
                    continue;
                }
                let ispace = launch.domain.extent();
                if !seen.insert((launch.name.clone(), ispace.clone())) {
                    continue;
                }
                cold.push(spec.plan_domain(&launch.name, &launch.domain).unwrap());
                work.push(Work { mapper: mapper_idx, task: launch.name.clone(), ispace });
            }
            mappers_out.push(MappleMapper::with_cache(spec, Arc::clone(cache)));
        }
    }
    (mappers_out, work, cold)
}

/// N threads × shuffled request orders × several rounds, with a machine
/// invalidation fired mid-run: every answer — cached, coalesced, or
/// recompiled after the purge — must equal the cold table.
#[test]
fn stress_mixed_shapes_with_midrun_invalidation_matches_cold_plans() {
    let mut desc = MachineDesc::paper_testbed(2);
    desc.gpus_per_node = 4;
    let cache = Arc::new(PlanCache::new(8, 64 << 20));
    let (mappers, work, cold) = fixture(&cache, &desc);
    assert!(work.len() >= APPS.len(), "fixture produced too little work");

    const THREADS: usize = 8;
    const ROUNDS: usize = 4;
    let machine = desc.cache_key();
    std::thread::scope(|scope| {
        for t in 0..THREADS {
            let mappers = &mappers;
            let work = &work;
            let cold = &cold;
            let cache = &cache;
            let machine = &machine;
            scope.spawn(move || {
                let mut rng = Rng::new(0xabcd + t as u64);
                let mut order: Vec<usize> = (0..work.len()).collect();
                for round in 0..ROUNDS {
                    rng.shuffle(&mut order);
                    for &i in &order {
                        let w = &work[i];
                        let plan = mappers[w.mapper].cached_plan(&w.task, &w.ispace).unwrap();
                        assert_eq!(
                            **plan.table(),
                            cold[i],
                            "thread {t} round {round}: {} {:?} diverged from cold plan",
                            w.task,
                            w.ispace
                        );
                    }
                    // One thread purges the whole machine between rounds,
                    // racing everyone else's in-flight lookups.
                    if t == 0 && round == ROUNDS / 2 {
                        cache.invalidate_machine(machine);
                    }
                }
            });
        }
    });

    let s = cache.stats();
    let total = (THREADS * ROUNDS * work.len()) as u64;
    assert_eq!(s.hits + s.misses, total, "every request is a hit or a miss: {s:?}");
    assert_eq!(s.misses, s.compiles + s.coalesced, "misses split into leaders+waiters: {s:?}");
    assert!(s.invalidations > 0, "the mid-run purge must drop entries: {s:?}");
    assert!(
        s.compiles >= work.len() as u64,
        "each distinct key compiles at least once (plus post-purge recompiles): {s:?}"
    );
}

/// Without invalidation or byte pressure, single-flight means each
/// distinct key is compiled exactly once regardless of thread count.
#[test]
fn single_flight_compiles_each_key_exactly_once_across_threads() {
    let mut desc = MachineDesc::paper_testbed(2);
    desc.gpus_per_node = 4;
    let cache = Arc::new(PlanCache::new(8, 256 << 20));
    let (mappers, work, cold) = fixture(&cache, &desc);

    const THREADS: usize = 8;
    std::thread::scope(|scope| {
        for t in 0..THREADS {
            let mappers = &mappers;
            let work = &work;
            let cold = &cold;
            scope.spawn(move || {
                let mut rng = Rng::new(0x51f7 + t as u64);
                let mut order: Vec<usize> = (0..work.len()).collect();
                rng.shuffle(&mut order);
                for &i in &order {
                    let w = &work[i];
                    let plan = mappers[w.mapper].cached_plan(&w.task, &w.ispace).unwrap();
                    assert_eq!(**plan.table(), cold[i], "{} {:?}", w.task, w.ispace);
                }
            });
        }
    });

    let s = cache.stats();
    let total = (THREADS * work.len()) as u64;
    assert_eq!(s.compiles, work.len() as u64, "exactly one compile per distinct key: {s:?}");
    assert_eq!(s.hits + s.coalesced + s.compiles, total, "{s:?}");
    assert_eq!(s.evictions, 0, "{s:?}");
    assert_eq!(s.invalidations, 0, "{s:?}");
    assert_eq!(s.entries, work.len() as u64, "{s:?}");
}
