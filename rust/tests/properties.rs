//! Cross-module property tests (randomized via the in-house harness).

use mapple::decompose::{decompose, greedy_grid, Objective};
use mapple::machine::point::Tuple;
use mapple::machine::space::ProcSpace;
use mapple::machine::topology::{MachineDesc, ProcKind};
use mapple::util::prng::Rng;
use mapple::util::proptest::check;
use std::collections::HashSet;

/// Any random chain of valid transformations remains a bijection from the
/// transformed space onto the physical processors.
#[test]
fn random_transform_chains_are_bijections() {
    check(
        "transform chains bijective",
        120,
        |r: &mut Rng| {
            let nodes = *r.choose(&[1usize, 2, 4]);
            let gpus = *r.choose(&[2usize, 4]);
            (nodes, gpus, r.next_u64())
        },
        |&(nodes, gpus, seed)| {
            let mut desc = MachineDesc::paper_testbed(nodes);
            desc.gpus_per_node = gpus;
            let mut space = ProcSpace::machine(&desc, ProcKind::Gpu);
            let mut r = Rng::new(seed);
            for _ in 0..r.range(0, 5) {
                let dims = space.dim();
                let choice = r.range(0, 3);
                space = match choice {
                    0 => {
                        let i = r.range(0, dims as i64 - 1) as usize;
                        let extent = space.size()[i];
                        let divisors: Vec<i64> = (1..=extent).filter(|d| extent % d == 0).collect();
                        let d = *r.choose(&divisors);
                        match space.split(i, d) {
                            Ok(s) => s,
                            Err(_) => space,
                        }
                    }
                    1 if dims >= 2 => {
                        let p = r.range(0, dims as i64 - 2) as usize;
                        match space.merge(p, p + 1) {
                            Ok(s) => s,
                            Err(_) => space,
                        }
                    }
                    _ if dims >= 2 => {
                        let p = r.range(0, dims as i64 - 1) as usize;
                        let q = r.range(0, dims as i64 - 1) as usize;
                        if p == q {
                            space
                        } else {
                            let (a, b) = (p.min(q), p.max(q));
                            match space.swap(a, b) {
                                Ok(s) => s,
                                Err(_) => space,
                            }
                        }
                    }
                    _ => space,
                };
            }
            // enumerate every coordinate; image must be exactly the
            // physical processor set
            let shape = space.size().clone();
            let rect = mapple::machine::point::Rect::from_extent(&shape);
            let mut seen = HashSet::new();
            for p in rect.points() {
                let proc = space.index(&p).map_err(|e| e)?;
                if proc.node >= nodes || proc.local >= gpus {
                    return Err(format!("out of range: {proc:?}"));
                }
                if !seen.insert((proc.node, proc.local)) {
                    return Err(format!("collision at {proc:?}"));
                }
            }
            if seen.len() != nodes * gpus {
                return Err(format!("image size {} != {}", seen.len(), nodes * gpus));
            }
            Ok(())
        },
    );
}

/// decompose is bounded below by AM-GM and above by greedy.
#[test]
fn decompose_sandwich_property() {
    check(
        "amgm <= decompose <= greedy",
        300,
        |r: &mut Rng| {
            let d = r.range(1, 256) as u64;
            let k = r.range(1, 3) as usize;
            let l: Vec<u64> = (0..k).map(|_| r.range(2, 4096) as u64).collect();
            (d, l)
        },
        |(d, l)| {
            let s = decompose(*d, l);
            let bound = Objective::amgm_lower_bound(*d, l);
            if s.objective < bound - 1e-9 {
                return Err(format!("beats AM-GM bound?! {} < {bound}", s.objective));
            }
            let g = Objective::Isotropic.eval(&greedy_grid(*d, l.len()), l);
            if s.objective > g + 1e-9 {
                return Err(format!("worse than greedy: {} > {g}", s.objective));
            }
            Ok(())
        },
    );
}

/// The DSL rejects malformed programs with diagnostics, never panics.
#[test]
fn malformed_programs_fail_gracefully() {
    let desc = MachineDesc::paper_testbed(2);
    let cases = [
        "def f(:",                                   // parse error
        "m = Machine(TPU)\n",                        // bad proc kind
        "x = unknown_name\n",                        // undefined global
        "m = Machine(GPU)\nx = m.split(0, 3)\n",     // non-dividing split
        "m = Machine(GPU)\nx = m.merge(1, 0)\n",     // merge needs p < q
        "m = Machine(GPU)\nx = m[9, 9]\n",           // index out of bounds
        "Backpressure t 1\nBackpressure t 1 1\n",    // trailing tokens
        "m = Machine(GPU)\ndef f(Tuple p, Tuple s):\n    return m[p[0] / 0, 0]\nIndexTaskMap f f\n",
    ];
    for src in cases {
        let r = mapple::mapple::MapperSpec::compile(src, &desc);
        if src.contains("p[0] / 0") {
            // body errors surface at call time, not compile time
            let spec = r.expect("compiles");
            let e = spec
                .map_point("f", &Tuple::from([1, 2]), &Tuple::from([4, 4]))
                .expect_err("division by zero must error");
            assert!(e.to_string().contains("division by zero"), "{e}");
        } else {
            assert!(r.is_err(), "should reject: {src}");
        }
    }
}

/// Simulated makespan is monotone in network bandwidth (more bandwidth
/// never hurts a fixed mapping).
#[test]
fn makespan_monotone_in_bandwidth() {
    use mapple::apps;
    use mapple::bench::{mapper_for, run, Flavor};
    check(
        "bandwidth monotonicity",
        20,
        |r: &mut Rng| (r.range(1, 4) as i64, r.range(1, 3) as usize),
        |&(aspect, nodes)| {
            let gpus = nodes * 4;
            let make = |ib_mult: f64| {
                let mut desc = MachineDesc::paper_testbed(nodes);
                desc.ib_bw *= ib_mult;
                desc.nvlink_bw *= ib_mult;
                let g = decompose(gpus as u64, &[512, (512 * aspect) as u64]);
                let app = apps::stencil(&apps::StencilParams {
                    x: 512,
                    y: 512 * aspect,
                    gx: g.factors[0] as i64,
                    gy: g.factors[1] as i64,
                    halo: 1,
                    steps: 2,
                });
                let m = mapper_for(&Flavor::Mapple, "stencil", &desc);
                run(&app, m.as_ref(), &desc).unwrap().makespan
            };
            let slow = make(0.5);
            let fast = make(2.0);
            if fast <= slow + 1e-12 {
                Ok(())
            } else {
                Err(format!("more bandwidth made it slower: {fast} > {slow}"))
            }
        },
    );
}
