//! Zero-allocation proof for the warmed serve hit path with tracing off.
//!
//! A counting `#[global_allocator]` wraps the system allocator. After
//! one cold compile warms the plan cache, repeated
//! `MappleMapper::cached_plan_hit` probes — the exact resolution path
//! the serve daemon's `plan` op takes — must perform **zero**
//! allocations while the obs collector is disabled: the probe walks
//! borrowed keys under a shard read lock, and every instrumentation
//! site costs one relaxed atomic load.
//!
//! This file holds a single test on purpose: the allocation counter is
//! process-global, so a concurrently running test in the same binary
//! would count its own allocations into our window.

use mapple::machine::point::Tuple;
use mapple::mapper::MappleMapper;
use mapple::mapple::MapperSpec;
use mapple::obs;
use mapple::serve::cache::PlanCache;
use mapple::serve::machine_for;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

static COUNTING: AtomicBool = AtomicBool::new(false);
static ALLOCS: AtomicU64 = AtomicU64::new(0);

/// Counts alloc/realloc calls while armed; frees are deliberately not
/// counted (dropping the returned `Arc` only decrements a refcount —
/// the cache keeps the plan alive).
struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn warmed_hit_path_allocates_nothing_with_tracing_disabled() {
    obs::stop();
    let desc = machine_for(2, 4);
    let src = mapple::apps::mappers::mapple_source("cannon").unwrap();
    let spec = MapperSpec::compile(src, &desc).unwrap();
    let mapper = MappleMapper::with_cache(spec, Arc::new(PlanCache::new(4, 1 << 20)));
    let task = "mm_step_0".to_string();
    let ispace = Tuple(vec![4, 4]);

    // Warm the cache (the one compile), then one untracked warm probe to
    // settle any lazy one-time initialization on the hit path.
    let (cold, hit) = mapper.cached_plan_hit(&task, &ispace).unwrap();
    assert!(!hit, "first probe compiles");
    let (warm, hit) = mapper.cached_plan_hit(&task, &ispace).unwrap();
    assert!(hit, "second probe is warm");
    assert_eq!(warm.digest(), cold.digest());
    drop((cold, warm));

    ALLOCS.store(0, Ordering::SeqCst);
    COUNTING.store(true, Ordering::SeqCst);
    for _ in 0..1000 {
        let (plan, hit) = mapper.cached_plan_hit(&task, &ispace).unwrap();
        assert!(hit);
        drop(plan);
    }
    COUNTING.store(false, Ordering::SeqCst);
    let allocs = ALLOCS.load(Ordering::SeqCst);
    assert_eq!(allocs, 0, "warmed hit path must be allocation-free, saw {allocs} allocations");
}
