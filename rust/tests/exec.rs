//! Executor differential suite: the concurrent multi-node executor
//! (`mapple::exec`) against the sequential §5.1 pipeline oracle.
//!
//! For all nine apps × {base, tuned, auto} mappers × machine shapes, the
//! executor's placements must equal the pipeline's exactly and its
//! transition log must contain exactly the oracle's transitions while
//! satisfying the same stage/dependence invariants on the measured
//! timeline ([`ExecResult::verify_against`]). On top of the differential:
//! worker-count invariance (same checksum/log/bytes for 1, 2, N lanes),
//! schedule determinism under a fixed tie-break seed, and typed plan
//! errors (no stringly matching between pipeline and executor).

mod common;

use common::build_app;
use mapple::apps::AppInstance;
use mapple::exec::{execute, ExecError, ExecOptions, ExecResult, KernelMode};
use mapple::machine::topology::MachineDesc;
use mapple::mapper::api::{Mapper, MapperAsMapping};
use mapple::mapper::MappleMapper;
use mapple::mapple::MapperSpec;
use mapple::sim::DefaultPolicies;
use mapple::tasking::deps::{analyze, Dependences};
use mapple::tasking::pipeline::{self, PipelineRun, PlanError};
use mapple::tune::{tune_with_ctx, EvalCtx, StrategyKind, TuneConfig};
use std::collections::HashMap;

const APPS: &[&str] = &[
    "cannon", "summa", "pumma", "johnson", "solomonik", "cosma", "stencil", "circuit", "pennant",
];

fn shape(nodes: usize, gpus: usize) -> MachineDesc {
    let mut d = MachineDesc::paper_testbed(nodes);
    d.gpus_per_node = gpus;
    d
}

/// The executor sweep: single node, multi-node, and the 4-GPU testbed
/// shape (a subset of the VM differential's six — each exec run spawns
/// real threads, so the suite stays seconds-fast).
fn exec_shapes() -> Vec<MachineDesc> {
    vec![shape(1, 2), shape(2, 2), shape(2, 4)]
}

fn run_exec(
    app: &AppInstance,
    mapper: &dyn Mapper,
    desc: &MachineDesc,
    opts: &ExecOptions,
) -> (ExecResult, PipelineRun, Dependences) {
    let deps = analyze(&app.launches, &app.env);
    let adapter = MapperAsMapping {
        mapper,
        num_nodes: desc.nodes,
        procs_per_node: desc.gpus_per_node,
    };
    let run = pipeline::run(&app.launches, &deps, &adapter, desc.nodes).unwrap();
    let exec = execute(&app.launches, &app.env, &deps, &run, desc, &adapter, opts).unwrap();
    (exec, run, deps)
}

fn mapper_from(src: &str, desc: &MachineDesc) -> MappleMapper {
    MappleMapper::new(MapperSpec::compile(src, desc).unwrap())
}

#[test]
fn exec_matches_pipeline_oracle_for_all_nine_apps_base_and_tuned() {
    use mapple::apps::mappers;
    for desc in exec_shapes() {
        let procs = desc.nodes * desc.gpus_per_node;
        for app_name in APPS {
            let sources = [
                ("base", mappers::mapple_source(app_name).unwrap()),
                ("tuned", mappers::tuned_source(app_name).unwrap()),
            ];
            for (flavor, src) in sources {
                let mapper = mapper_from(src, &desc);
                let app = build_app(app_name, procs);
                let (exec, run, deps) =
                    run_exec(&app, &mapper, &desc, &ExecOptions::default());
                exec.verify_against(&run, &deps).unwrap_or_else(|e| {
                    panic!(
                        "{app_name} {flavor} ({}n×{}g): {e}",
                        desc.nodes, desc.gpus_per_node
                    )
                });
                assert_eq!(exec.tasks as i64, app.total_points(), "{app_name} {flavor}");
                assert!(exec.wall_seconds > 0.0);
            }
        }
    }
}

#[test]
fn exec_matches_pipeline_oracle_under_auto_mappers() {
    // Budget-limited autotune (still the real search + simulator scoring)
    // for every app, then the same differential as base/tuned.
    let desc = shape(2, 2);
    for app_name in APPS {
        let app = build_app(app_name, 4);
        let ctx =
            EvalCtx::from_parts(app_name, vec![desc.clone()], vec![build_app(app_name, 4)]);
        let mut cfg = TuneConfig::quick(app_name, &desc);
        cfg.budget = 8;
        cfg.batch = 4;
        cfg.strategy = StrategyKind::Beam(2);
        let result = tune_with_ctx(&cfg, &ctx).unwrap_or_else(|e| panic!("{app_name}: {e}"));
        let mapper = MappleMapper::new(result.best.build(&desc).unwrap());
        let (exec, run, deps) = run_exec(&app, &mapper, &desc, &ExecOptions::default());
        exec.verify_against(&run, &deps)
            .unwrap_or_else(|e| panic!("{app_name} auto: {e}"));
    }
}

#[test]
fn results_are_invariant_under_worker_count() {
    use mapple::apps::mappers;
    let desc = shape(2, 2);
    for app_name in ["cannon", "stencil", "pennant"] {
        let mapper = mapper_from(mappers::mapple_source(app_name).unwrap(), &desc);
        let app = build_app(app_name, 4);
        let one_lane = ExecOptions { lanes: 1, ..ExecOptions::default() };
        let baseline = run_exec(&app, &mapper, &desc, &one_lane).0;
        for lanes in [2usize, 16] {
            let opts = ExecOptions { lanes, ..ExecOptions::default() };
            let r = run_exec(&app, &mapper, &desc, &opts).0;
            assert_eq!(r.checksum, baseline.checksum, "{app_name} lanes={lanes}");
            assert_eq!(r.intra_bytes, baseline.intra_bytes, "{app_name} lanes={lanes}");
            assert_eq!(r.inter_bytes, baseline.inter_bytes, "{app_name} lanes={lanes}");
            // (peak_resident and wall_seconds are genuinely
            // schedule-dependent — interleaving of inserts/GC across a
            // node's procs — and are deliberately not compared.)
            assert_eq!(r.placements, baseline.placements, "{app_name} lanes={lanes}");
            assert_eq!(r.canonical_log(), baseline.canonical_log(), "{app_name} lanes={lanes}");
            assert_eq!(r.per_proc, baseline.per_proc, "{app_name} lanes={lanes}");
        }
    }
}

#[test]
fn fast_kernels_match_naive_bitwise_for_all_nine_apps() {
    // The blocked GEMM + pooled buffers + zero-copy gathers of
    // KernelMode::Fast must be representation changes only: every app's
    // checksum, byte counters, log, and placements equal the naive
    // reference kernels' exactly (same per-element f32 operation order).
    use mapple::apps::mappers;
    let desc = shape(2, 2);
    for app_name in APPS {
        let mapper = mapper_from(mappers::mapple_source(app_name).unwrap(), &desc);
        let app = build_app(app_name, 4);
        let naive_opts = ExecOptions { kernels: KernelMode::Naive, ..ExecOptions::default() };
        let fast_opts = ExecOptions { kernels: KernelMode::Fast, ..ExecOptions::default() };
        let naive = run_exec(&app, &mapper, &desc, &naive_opts).0;
        let fast = run_exec(&app, &mapper, &desc, &fast_opts).0;
        assert_eq!(fast.checksum, naive.checksum, "{app_name}");
        assert_eq!(fast.intra_bytes, naive.intra_bytes, "{app_name}");
        assert_eq!(fast.inter_bytes, naive.inter_bytes, "{app_name}");
        assert_eq!(fast.placements, naive.placements, "{app_name}");
        assert_eq!(fast.canonical_log(), naive.canonical_log(), "{app_name}");
    }
}

#[test]
fn kernel_modes_agree_across_worker_counts_and_seeds() {
    // The bitwise fast≡naive invariant must also hold under lane caps
    // and schedule reorderings (pool reuse patterns differ per schedule;
    // contents must not).
    use mapple::apps::mappers;
    let desc = shape(2, 2);
    for app_name in ["cannon", "summa", "stencil"] {
        let mapper = mapper_from(mappers::mapple_source(app_name).unwrap(), &desc);
        let app = build_app(app_name, 4);
        let naive_opts = ExecOptions { kernels: KernelMode::Naive, ..ExecOptions::default() };
        let reference = run_exec(&app, &mapper, &desc, &naive_opts).0;
        for (lanes, seed) in [(1usize, 0u64), (2, 9), (16, 3)] {
            let opts = ExecOptions { lanes, seed, kernels: KernelMode::Fast };
            let fast = run_exec(&app, &mapper, &desc, &opts).0;
            assert_eq!(
                fast.checksum, reference.checksum,
                "{app_name} lanes={lanes} seed={seed}"
            );
            assert_eq!(fast.canonical_log(), reference.canonical_log(), "{app_name}");
        }
    }
}

#[test]
fn schedule_is_deterministic_in_the_seed() {
    use mapple::apps::mappers;
    let desc = shape(2, 2);
    let mapper = mapper_from(mappers::mapple_source("summa").unwrap(), &desc);
    let app = build_app("summa", 4);
    let seven = ExecOptions { seed: 7, ..ExecOptions::default() };
    let a = run_exec(&app, &mapper, &desc, &seven).0;
    let b = run_exec(&app, &mapper, &desc, &seven).0;
    // same seed → identical per-processor execution order
    assert_eq!(a.per_proc, b.per_proc);
    assert_eq!(a.checksum, b.checksum);
    // a different seed may reorder independent tasks, but every result
    // the executor reports is schedule-invariant
    let eight = ExecOptions { seed: 8, ..ExecOptions::default() };
    let c = run_exec(&app, &mapper, &desc, &eight).0;
    assert_eq!(c.checksum, a.checksum);
    assert_eq!(c.placements, a.placements);
    assert_eq!(c.canonical_log(), a.canonical_log());
    assert_eq!((c.intra_bytes, c.inter_bytes), (a.intra_bytes, a.inter_bytes));
}

#[test]
fn gc_directive_forces_refetch_without_changing_results() {
    // The mapper's GarbageCollect directive drops the consuming
    // processor's instance after use: a later re-read of the same tile
    // must pay the data movement again. That effect is fixed at plan
    // time, so the byte counters compare deterministically; the data
    // itself must be unaffected.
    use mapple::machine::point::{Rect, Tuple};
    use mapple::sim::MappingPolicies;
    use mapple::tasking::deps::DataEnv;
    use mapple::tasking::region::{LogicalRegion, Partition, Privilege, RegionId};
    use mapple::tasking::task::{IndexLaunch, RegionReq};

    struct GcFirstRead;
    impl MappingPolicies for GcFirstRead {
        fn should_gc(&self, task: &str, _arg: usize) -> bool {
            task == "read1"
        }
    }

    // One region, one tile per node-column; read twice on the far node.
    let mut env = DataEnv::default();
    let rid = env.add_region(LogicalRegion {
        id: RegionId(0),
        name: "A".into(),
        extent: Tuple::from([8, 8]),
        elem_bytes: 4,
    });
    let part = Partition::block(env.region(rid), &Tuple::from([2, 2])).unwrap();
    let pidx = env.add_partition(part);
    let dom = Rect::from_extent(&Tuple::from([2, 2]));
    let transpose = |priv_: Privilege| {
        RegionReq::shifted(rid, pidx, priv_, vec![1, 0], Tuple::from([0, 0]))
    };
    let launches = vec![
        IndexLaunch::new(0, "init", dom.clone())
            .with_req(RegionReq::tiled(rid, pidx, Privilege::WriteOnly)),
        IndexLaunch::new(1, "read1", dom.clone()).with_req(transpose(Privilege::ReadOnly)),
        IndexLaunch::new(2, "read2", dom).with_req(transpose(Privilege::ReadOnly)),
    ];
    let desc = shape(2, 2);
    let deps = analyze(&launches, &env);
    let mapper = mapper_from(mapple::apps::mappers::mapple_source("cannon").unwrap(), &desc);
    let adapter = MapperAsMapping { mapper: &mapper, num_nodes: 2, procs_per_node: 2 };
    let run = pipeline::run(&launches, &deps, &adapter, 2).unwrap();
    let opts = ExecOptions::default();
    let base = execute(&launches, &env, &deps, &run, &desc, &DefaultPolicies, &opts).unwrap();
    let gc = execute(&launches, &env, &deps, &run, &desc, &GcFirstRead, &opts).unwrap();
    assert!(
        gc.total_bytes() > base.total_bytes(),
        "GC'd instance must be re-fetched: {} vs {}",
        gc.total_bytes(),
        base.total_bytes()
    );
    assert_eq!(gc.checksum, base.checksum, "GC must not change data contents");
}

#[test]
fn bench_flavor_integration_runs_exec() {
    // The Flavor surface shared by `mapple run`/`mapple exec` and the
    // bench harnesses drives the executor end-to-end.
    use mapple::bench::{mapper_for, run_exec as bench_run_exec, Flavor};
    let desc = shape(1, 2);
    let flavor = Flavor::parse("mapple").unwrap();
    assert_eq!(flavor.name(), "mapple");
    assert!(Flavor::parse("nope").is_err());
    let mapper = mapper_for(&flavor, "cannon", &desc);
    let app = build_app("cannon", 2);
    let r = bench_run_exec(&app, mapper.as_ref(), &desc, &ExecOptions::default()).unwrap();
    assert_eq!(r.tasks as i64, app.total_points());
    assert!(r.wall_seconds > 0.0);
}

#[test]
fn executor_plan_errors_are_typed() {
    // A PipelineRun without launch plans must surface as a typed
    // ExecError::Plan — no string matching between the two subsystems.
    let desc = shape(2, 2);
    let app = build_app("cannon", 4);
    let deps = analyze(&app.launches, &app.env);
    let hollow = PipelineRun { placements: HashMap::new(), log: Vec::new(), plans: HashMap::new() };
    let e = execute(
        &app.launches,
        &app.env,
        &deps,
        &hollow,
        &desc,
        &DefaultPolicies,
        &ExecOptions::default(),
    )
    .unwrap_err();
    match e {
        ExecError::Plan(PlanError::Mapping { ref task, .. }) => {
            assert_eq!(task, "init_a");
        }
        other => panic!("expected typed plan error, got {other:?}"),
    }
    // And the pipeline's own empty-domain rejection is the same type.
    use mapple::machine::point::{Rect, Tuple};
    use mapple::tasking::pipeline::IndexMapping;
    let mapper = mapper_from(mapple::apps::mappers::mapple_source("cannon").unwrap(), &desc);
    let adapter = MapperAsMapping { mapper: &mapper, num_nodes: 2, procs_per_node: 2 };
    let empty = Rect::new(Tuple::from([1, 1]), Tuple::from([0, 0]));
    match adapter.plan("mm_step_0", &empty, 2) {
        Err(PlanError::EmptyDomain { task }) => assert_eq!(task, "mm_step_0"),
        other => panic!("expected EmptyDomain, got {other:?}"),
    }
}
