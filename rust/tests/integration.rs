//! Cross-module integration tests:
//!   1. Mapple ↔ expert mapper decision equivalence (the §6.1 fidelity
//!      check: "we manually verify that both approaches make identical
//!      mapping decisions"), here automated over every app and machine.
//!   2. Full pipeline runs (DSL → pipeline → simulator) for all nine apps.
//!   3. Pipeline-invariant validation on real app programs.

use mapple::apps::{self, mappers};
use mapple::machine::topology::MachineDesc;
use mapple::mapper::api::{Mapper, TaskCtx};
use mapple::mapper::expert::expert_for;
use mapple::mapper::MappleMapper;
use mapple::mapple::MapperSpec;
use mapple::tasking::{analyze, pipeline};

fn desc(nodes: usize) -> MachineDesc {
    MachineDesc::paper_testbed(nodes)
}

fn build_app(name: &str, procs: usize) -> apps::AppInstance {
    match name {
        "cannon" => apps::cannon(64, procs),
        "summa" => apps::summa(64, procs),
        "pumma" => apps::pumma(64, procs),
        "johnson" => apps::johnson(64, procs),
        "solomonik" => apps::solomonik(64, procs),
        "cosma" => apps::cosma(64, procs),
        "stencil" => {
            // tile grid matching the proc count (2D)
            let g = mapple::decompose::decompose(procs as u64, &[256, 256]);
            apps::stencil(&apps::StencilParams {
                x: 256,
                y: 256,
                gx: g.factors[0] as i64,
                gy: g.factors[1] as i64,
                halo: 1,
                steps: 2,
            })
        }
        "circuit" => apps::circuit(&apps::CircuitParams {
            pieces: procs as i64,
            nodes_per_piece: 64,
            wires_per_piece: 128,
            pct_shared: 10,
            loops: 2,
        }),
        "pennant" => apps::pennant(&apps::PennantParams {
            chunks: procs as i64,
            zones_per_chunk: 128,
            cycles: 2,
        }),
        other => panic!("unknown app {other}"),
    }
}

const APPS: &[&str] = &[
    "cannon", "summa", "pumma", "johnson", "solomonik", "cosma", "stencil", "circuit", "pennant",
];

#[test]
fn mapple_matches_expert_decisions() {
    // The Table 1 fidelity property: for every app, the Mapple mapper and
    // the hand-written low-level mapper place every point task of every
    // launch identically.
    for nodes in [1usize, 2, 4] {
        let d = desc(nodes);
        for app_name in APPS {
            let app = build_app(app_name, d.nodes * d.gpus_per_node);
            let spec =
                MapperSpec::compile(mappers::mapple_source(app_name).unwrap(), &d).unwrap();
            let mapple = MappleMapper::new(spec);
            let expert = expert_for(app_name, d.nodes, d.gpus_per_node).unwrap();
            for launch in &app.launches {
                let ispace = launch.domain.extent();
                let ctx = TaskCtx {
                    task_name: &launch.name,
                    launch_domain: &launch.domain,
                    num_nodes: d.nodes,
                    procs_per_node: d.gpus_per_node,
                };
                for pt in launch.domain.points() {
                    let a = mapple.map_task(&ctx, &pt, &ispace).unwrap_or_else(|e| {
                        panic!("{app_name}/{} mapple failed: {e}", launch.name)
                    });
                    let b = expert.map_task(&ctx, &pt, &ispace).unwrap_or_else(|e| {
                        panic!("{app_name}/{} expert failed: {e}", launch.name)
                    });
                    assert_eq!(
                        a, b,
                        "{app_name}/{} point {pt:?} (nodes={nodes}): mapple {a:?} vs expert {b:?}",
                        launch.name
                    );
                }
            }
        }
    }
}

#[test]
fn all_apps_run_under_both_mappers() {
    let d = desc(2);
    for app_name in APPS {
        let app = build_app(app_name, d.nodes * d.gpus_per_node);
        let expert = expert_for(app_name, d.nodes, d.gpus_per_node).unwrap();
        let out = apps::run_app(&app, expert.as_ref(), &d)
            .unwrap_or_else(|e| panic!("{app_name} expert: {e}"));
        assert!(out.sim.oom.is_none(), "{app_name} expert OOM: {:?}", out.sim.oom);
        assert!(out.sim.makespan > 0.0, "{app_name}");

        let spec = MapperSpec::compile(mappers::mapple_source(app_name).unwrap(), &d).unwrap();
        let mapple = MappleMapper::new(spec);
        let out2 = apps::run_app(&app, &mapple, &d)
            .unwrap_or_else(|e| panic!("{app_name} mapple: {e}"));
        // identical decisions → identical simulated time (§6.1 "matching
        // performance ... any overhead introduced by Mapple is negligible")
        let rel = (out.sim.makespan - out2.sim.makespan).abs() / out.sim.makespan;
        assert!(
            rel < 1e-9,
            "{app_name}: expert {} vs mapple {}",
            out.sim.makespan,
            out2.sim.makespan
        );
    }
}

#[test]
fn tuned_mappers_compile_and_run() {
    let d = desc(2);
    for app_name in APPS {
        let app = build_app(app_name, d.nodes * d.gpus_per_node);
        let spec = MapperSpec::compile(mappers::tuned_source(app_name).unwrap(), &d).unwrap();
        let tuned = MappleMapper::new(spec);
        let out = apps::run_app(&app, &tuned, &d)
            .unwrap_or_else(|e| panic!("{app_name} tuned: {e}"));
        assert!(out.sim.oom.is_none(), "{app_name} tuned OOM");
    }
}

#[test]
fn pipeline_invariants_hold_on_real_apps() {
    let d = desc(2);
    for app_name in ["cannon", "stencil", "circuit"] {
        let app = build_app(app_name, d.nodes * d.gpus_per_node);
        let deps = analyze(&app.launches, &app.env);
        let expert = expert_for(app_name, d.nodes, d.gpus_per_node).unwrap();
        let adapter = mapple::mapper::MapperAsMapping {
            mapper: expert.as_ref(),
            num_nodes: d.nodes,
            procs_per_node: d.gpus_per_node,
        };
        let run = pipeline::run(&app.launches, &deps, &adapter, d.nodes).unwrap();
        pipeline::validate(&run, &deps).unwrap_or_else(|e| panic!("{app_name}: {e}"));
        // every point task of every launch got a placement
        let total: i64 = app.launches.iter().map(|l| l.num_points()).sum();
        assert_eq!(run.placements.len() as i64, total, "{app_name}");
    }
}

#[test]
fn slice_task_agrees_with_map_task() {
    // The default slice_task must distribute exactly like per-point
    // map_task calls (Legion's slice/point duality).
    let d = desc(2);
    let app = build_app("cannon", 8);
    let expert = expert_for("cannon", d.nodes, d.gpus_per_node).unwrap();
    for launch in &app.launches {
        let ispace = launch.domain.extent();
        let ctx = TaskCtx {
            task_name: &launch.name,
            launch_domain: &launch.domain,
            num_nodes: d.nodes,
            procs_per_node: d.gpus_per_node,
        };
        let out = expert
            .slice_task(&ctx, &mapple::mapper::SliceTaskInput { domain: launch.domain.clone() })
            .unwrap();
        let covered: i64 = out.slices.iter().map(|s| s.domain.volume()).sum();
        assert_eq!(covered, launch.num_points());
        for slice in &out.slices {
            for pt in slice.domain.points() {
                let direct = expert.map_task(&ctx, &pt, &ispace).unwrap();
                assert_eq!(direct, slice.proc);
            }
        }
    }
}
