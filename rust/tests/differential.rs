//! Differential tests: the batched MappingPlan VM against the
//! tree-walking interpreter oracle.
//!
//! The lowering pass + VM (mapple::lower / mapple::vm) replace the
//! per-point tree walk on the hot path; the tree walker stays as the
//! reference semantics. These tests prove, for every shipped mapper
//! (all nine apps, baseline and tuned), every launch of a real app
//! instance, and several machine shapes, that
//!
//!   VM placement(point) == interpreter placement(point)
//!
//! point-for-point — plus randomized language-coverage programs driven by
//! the in-house property harness.

mod common;

use common::{build_app, machine_shapes};
use mapple::apps::mappers;
use mapple::machine::point::{Rect, Tuple};
use mapple::machine::topology::MachineDesc;
use mapple::mapple::MapperSpec;
use mapple::util::prng::Rng;
use mapple::util::proptest::check;

const APPS: &[&str] = &[
    "cannon", "summa", "pumma", "johnson", "solomonik", "cosma", "stencil", "circuit", "pennant",
];

/// The headline differential property: for all nine apps' mappers
/// (baseline and tuned), across machine shapes, the compiled MappingPlan
/// produces exactly the tree-walker's placements on every launch.
#[test]
fn vm_placements_equal_interp_for_all_nine_apps() {
    for desc in machine_shapes() {
        let procs = desc.nodes * desc.gpus_per_node;
        for app_name in APPS {
            let sources = [
                ("base", mappers::mapple_source(app_name).unwrap()),
                ("tuned", mappers::tuned_source(app_name).unwrap()),
            ];
            for (flavor, src) in sources {
                let spec = MapperSpec::compile(src, &desc)
                    .unwrap_or_else(|e| panic!("{app_name} {flavor}: {e}"));
                let app = build_app(app_name, procs);
                for launch in &app.launches {
                    // the test must not be vacuous: the mapping function
                    // has to actually run on the VM, not the fallback
                    let func = spec
                        .mapping_fn(&launch.name)
                        .unwrap_or_else(|| panic!("{app_name}: no mapping for {}", launch.name));
                    assert!(
                        spec.plan.supports(func),
                        "{app_name} {flavor}: '{func}' fell back to the tree walker"
                    );
                    let table = spec.plan_domain(&launch.name, &launch.domain).unwrap_or_else(
                        |e| {
                            panic!(
                                "{app_name} {flavor} {} ({}n×{}g): {e}",
                                launch.name, desc.nodes, desc.gpus_per_node
                            )
                        },
                    );
                    let ispace = launch.domain.extent();
                    for p in launch.domain.points() {
                        let oracle = spec
                            .map_point(&launch.name, &p, &ispace)
                            .unwrap_or_else(|e| panic!("{app_name} oracle {}: {e}", launch.name));
                        assert_eq!(
                            table.get(&p),
                            Some(oracle),
                            "{app_name} {flavor} {} point {p:?} ({}n×{}g)",
                            launch.name,
                            desc.nodes,
                            desc.gpus_per_node
                        );
                    }
                }
            }
        }
    }
}

/// Language-coverage corpus: mappers exercising constructs the nine app
/// mappers don't all hit (if/elif/else chains, and/or, builtins, negative
/// indexing, nested helper calls, hoisted-then-overwritten locals).
const COVERAGE_MAPPERS: &[&str] = &[
    // ternary + cyclic over a merged space
    "m = Machine(GPU)\n\
     m1 = m.merge(0, 1)\n\
     def f(Tuple p, Tuple s):\n    \
         g = s[0] > s[1] ? s[0] : s[1]\n    \
         return m1[(p[0] * g + p[1]) % m1.size[0]]\n",
    // if/elif/else with and/or
    "m = Machine(GPU)\n\
     def f(Tuple p, Tuple s):\n    \
         if p[0] == 0 and p[1] == 0:\n        \
             return m[0, 0]\n    \
         elif p[0] == 0 or p[1] == 0:\n        \
             return m[p[0] % m.size[0], 0]\n    \
         else:\n        \
             return m[p[0] % m.size[0], p[1] % m.size[1]]\n",
    // builtins + helper composition
    "m = Machine(GPU)\n\
     def helper(Tuple p, Tuple s):\n    \
         return min(p) + max(s) + len(p) + abs(p[0] - s[1]) + prod(p + 1)\n\
     def f(Tuple p, Tuple s):\n    \
         v = helper(p, s)\n    \
         return m[v % m.size[0], v % m.size[1]]\n",
    // negative tuple index + slice + linearize
    "m = Machine(GPU)\n\
     def f(Tuple p, Tuple s):\n    \
         lin = linearize(p, s)\n    \
         tail = s[1:]\n    \
         return m[(lin + tail[0] + p[-1]) % m.size[0], 0]\n",
    // hoisted local overwritten per point (restore-set stress)
    "m = Machine(GPU)\n\
     def f(Tuple p, Tuple s):\n    \
         x = s[0] + s[1]\n    \
         x = x * 3 + p[0] * 2 + p[1]\n    \
         return m[x % m.size[0], x % m.size[1]]\n",
    // generator + splat indexing over a transformed space
    "m = Machine(GPU)\n\
     def f(Tuple p, Tuple s):\n    \
         m2 = m.swap(0, 1)\n    \
         idx = tuple(p[i] % m2.size[i] for i in (0, 1))\n    \
         return m2[*idx]\n",
];

#[test]
fn vm_matches_interp_on_language_coverage_corpus() {
    check(
        "vm ≡ interp on coverage corpus",
        96,
        |r: &mut Rng| {
            let which = r.range(0, COVERAGE_MAPPERS.len() as i64 - 1) as usize;
            let nodes = *r.choose(&[1usize, 2, 4]);
            let gpus = *r.choose(&[2usize, 4]);
            let sx = r.range(2, 9);
            let sy = r.range(2, 9);
            (which, nodes, gpus, sx, sy)
        },
        |&(which, nodes, gpus, sx, sy)| {
            let mut desc = MachineDesc::paper_testbed(nodes);
            desc.gpus_per_node = gpus;
            let src = COVERAGE_MAPPERS[which];
            let spec = MapperSpec::compile(src, &desc).map_err(|e| e.to_string())?;
            if !spec.plan.supports("f") {
                return Err(format!("corpus mapper {which} did not lower"));
            }
            let ispace = Tuple::from([sx, sy]);
            let dom = Rect::from_extent(&ispace);
            let table = spec.plan.eval_domain("f", &dom).map_err(|e| e.to_string())?;
            for p in dom.points() {
                let oracle = spec
                    .interp
                    .map_point("f", &p, &ispace)
                    .map_err(|e| format!("oracle: {e}"))?;
                if table.get(&p) != Some(oracle) {
                    return Err(format!(
                        "mapper {which} ({nodes}n×{gpus}g, ispace {ispace:?}): VM {:?} != interp {oracle:?} at {p:?}",
                        table.get(&p)
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Error-path agreement: when the oracle rejects a program at runtime,
/// the VM must reject it too (messages may differ; outcomes must agree).
#[test]
fn vm_and_interp_agree_on_failures() {
    let desc = MachineDesc::paper_testbed(2);
    let cases = [
        // non-processor return
        "m = Machine(GPU)\ndef f(Tuple p, Tuple s):\n    return 7\n",
        // division by zero
        "m = Machine(GPU)\ndef f(Tuple p, Tuple s):\n    return m[p[0] / 0, 0]\n",
        // out-of-bounds space index
        "m = Machine(GPU)\ndef f(Tuple p, Tuple s):\n    return m[99, 99]\n",
        // unbounded recursion
        "m = Machine(GPU)\ndef f(Tuple p, Tuple s):\n    return f(p, s)\n",
    ];
    let ispace = Tuple::from([2, 2]);
    let dom = Rect::from_extent(&ispace);
    for src in cases {
        let spec = MapperSpec::compile(src, &desc).unwrap();
        assert!(spec.plan.supports("f"), "{src}");
        let vm = spec.plan.eval_domain("f", &dom);
        let oracle = spec.interp.map_point("f", &Tuple::from([0, 0]), &ispace);
        assert!(vm.is_err(), "VM accepted: {src}");
        assert!(oracle.is_err(), "interp accepted: {src}");
    }
}

/// The MappleMapper's batched tables must match per-point oracle calls
/// through the public Mapper interface as well (cache + plan layers).
#[test]
fn mapper_tables_equal_oracle_through_public_interface() {
    use mapple::mapper::api::{Mapper, TaskCtx};
    use mapple::mapper::MappleMapper;
    let desc = MachineDesc::paper_testbed(2);
    for app_name in APPS {
        let spec = MapperSpec::compile(mappers::mapple_source(app_name).unwrap(), &desc).unwrap();
        let mapper = MappleMapper::new(spec);
        let app = build_app(app_name, desc.nodes * desc.gpus_per_node);
        for launch in &app.launches {
            let ispace = launch.domain.extent();
            let ctx = TaskCtx {
                task_name: &launch.name,
                launch_domain: &launch.domain,
                num_nodes: desc.nodes,
                procs_per_node: desc.gpus_per_node,
            };
            let table = mapper.build_plan(&ctx, &launch.domain).unwrap();
            for p in launch.domain.points() {
                let oracle = mapper.spec.map_point(&launch.name, &p, &ispace).unwrap();
                assert_eq!(table.get(&p), Some(oracle), "{app_name}/{} {p:?}", launch.name);
                assert_eq!(
                    mapper.map_task(&ctx, &p, &ispace).unwrap(),
                    oracle,
                    "{app_name}/{} {p:?}",
                    launch.name
                );
            }
        }
    }
}
