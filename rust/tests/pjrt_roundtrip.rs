//! Integration test: the full AOT bridge — HLO text artifacts produced by
//! python/compile/aot.py load, compile, and execute with correct numerics
//! through the Rust PJRT runtime. Requires `make artifacts` first; tests
//! are skipped (pass trivially) when artifacts are absent so plain
//! `cargo test` works pre-build.

use mapple::runtime::KernelRegistry;

fn registry() -> Option<KernelRegistry> {
    let reg = KernelRegistry::cpu("artifacts").expect("PJRT CPU client");
    if reg.available("matmul_tile_16") {
        Some(reg)
    } else {
        eprintln!("artifacts/ not built — skipping PJRT round-trip tests");
        None
    }
}

fn cpu_gemm_acc(a: &[f32], b: &[f32], c: &[f32], n: usize) -> Vec<f32> {
    let mut out = c.to_vec();
    for i in 0..n {
        for j in 0..n {
            let mut acc = 0f32;
            for k in 0..n {
                acc += a[i * n + k] * b[k * n + j];
            }
            out[i * n + j] += acc;
        }
    }
    out
}

#[test]
fn gemm_artifact_matches_reference() {
    let Some(reg) = registry() else { return };
    let kernel = reg.load("matmul_tile_16").expect("load+compile");
    let n = 16usize;
    let a: Vec<f32> = (0..n * n).map(|i| (i % 7) as f32 * 0.5 - 1.0).collect();
    let b: Vec<f32> = (0..n * n).map(|i| (i % 5) as f32 * 0.25).collect();
    let c: Vec<f32> = (0..n * n).map(|i| (i % 3) as f32).collect();
    let shape = [n as i64, n as i64];
    let out = kernel
        .run_f32(&[(&a, &shape), (&b, &shape), (&c, &shape)])
        .expect("execute");
    assert_eq!(out.len(), 1);
    let want = cpu_gemm_acc(&a, &b, &c, n);
    for (i, (&g, &w)) in out[0].iter().zip(&want).enumerate() {
        assert!((g - w).abs() < 1e-3, "idx {i}: {g} vs {w}");
    }
}

#[test]
fn stencil_artifact_fixed_point() {
    let Some(reg) = registry() else { return };
    let kernel = reg.load("stencil5_32x32").expect("load+compile");
    let (x, y) = (32usize, 32usize);
    let grid = vec![2.5f32; x * y];
    let ns = vec![2.5f32; y];
    let we = vec![2.5f32; x];
    let out = kernel
        .run_f32(&[
            (&grid, &[x as i64, y as i64]),
            (&ns, &[1, y as i64]),
            (&ns, &[1, y as i64]),
            (&we, &[x as i64, 1]),
            (&we, &[x as i64, 1]),
        ])
        .expect("execute");
    // weights sum to 1 → constant field is a fixed point
    for &v in &out[0] {
        assert!((v - 2.5).abs() < 1e-5, "{v}");
    }
}

#[test]
fn kernel_input_validation() {
    let Some(reg) = registry() else { return };
    let kernel = reg.load("matmul_tile_16").expect("load");
    let bad = vec![0f32; 10];
    assert!(kernel.run_f32(&[(&bad, &[16, 16])]).is_err());
}

#[test]
fn registry_caches_compiles() {
    let Some(reg) = registry() else { return };
    let a = reg.load("matmul_tile_32").unwrap();
    let b = reg.load("matmul_tile_32").unwrap();
    assert!(std::rc::Rc::ptr_eq(&a, &b), "second load must hit the cache");
}
