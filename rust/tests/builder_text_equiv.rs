//! Builder ↔ text equivalence suite: the typed `mapple::build` front-end
//! and the `.mpl` text front-end must be indistinguishable artifacts.
//!
//! For all 18 shipped mapper sources (nine apps × baseline/tuned), the
//! builder-reconstructed [`MapperSpec`] and the text-compiled one must
//! produce identical `PlacementTable`s for every launch of a real app
//! instance, across the differential machine-shape sweep — and identical
//! directive tables. A randomized property test additionally drives
//! arbitrary builder transform chains (`split`/`merge`/`swap`/`slice`/
//! `auto_split`) against the eagerly transformed `ProcSpace` as a third,
//! independent oracle.

mod common;

use common::{build_app, machine_shapes};
use mapple::apps::builder_mappers::{built_spec, BUILT_APPS};
use mapple::apps::mappers;
use mapple::machine::point::{Rect, Tuple};
use mapple::machine::space::ProcSpace;
use mapple::machine::topology::{MachineDesc, ProcKind};
use mapple::mapple::build::MapperBuilder;
use mapple::mapple::MapperSpec;
use mapple::util::prng::Rng;
use mapple::util::proptest::check;

fn text_spec(app: &str, tuned: bool, desc: &MachineDesc) -> MapperSpec {
    let src = if tuned {
        mappers::tuned_source(app).unwrap()
    } else {
        mappers::mapple_source(app).unwrap()
    };
    MapperSpec::compile(src, desc).unwrap_or_else(|e| panic!("{app} tuned={tuned}: {e}"))
}

/// The headline equivalence property: builder-made specs place every
/// launch of every app exactly like their text-compiled twins, on every
/// machine shape, through the same MappingPlan execution path.
#[test]
fn builder_placements_equal_text_for_all_18_mappers() {
    for desc in machine_shapes() {
        let procs = desc.nodes * desc.gpus_per_node;
        for app in BUILT_APPS {
            let instance = build_app(app, procs);
            for tuned in [false, true] {
                let text = text_spec(app, tuned, &desc);
                let built = built_spec(app, tuned, &desc)
                    .unwrap_or_else(|e| panic!("{app} tuned={tuned}: {e}"));
                for launch in &instance.launches {
                    // both sides must run compiled bytecode, not the
                    // tree-walker fallback
                    for spec in [&text, &built] {
                        let func = spec.mapping_fn(&launch.name).unwrap_or_else(|| {
                            panic!("{app}: no mapping for {}", launch.name)
                        });
                        assert!(
                            spec.plan.supports(func),
                            "{app} tuned={tuned}: '{func}' fell back to the tree walker"
                        );
                    }
                    let a = text.plan_domain(&launch.name, &launch.domain).unwrap_or_else(
                        |e| panic!("{app} tuned={tuned} {} text: {e}", launch.name),
                    );
                    let b = built.plan_domain(&launch.name, &launch.domain).unwrap_or_else(
                        |e| panic!("{app} tuned={tuned} {} builder: {e}", launch.name),
                    );
                    assert_eq!(
                        a, b,
                        "{app} tuned={tuned} {} ({}n×{}g): builder table differs",
                        launch.name, desc.nodes, desc.gpus_per_node
                    );
                }
            }
        }
    }
}

/// Directive-table equivalence: the tables the simulator's policy path
/// consumes must be identical field-for-field.
#[test]
fn builder_directive_tables_equal_text_for_all_18_mappers() {
    let desc = MachineDesc::paper_testbed(2);
    for app in BUILT_APPS {
        for tuned in [false, true] {
            let text = text_spec(app, tuned, &desc);
            let built = built_spec(app, tuned, &desc).unwrap();
            assert_eq!(built.index_task_maps, text.index_task_maps, "{app} tuned={tuned}");
            assert_eq!(built.task_maps, text.task_maps, "{app} tuned={tuned}");
            assert_eq!(built.regions, text.regions, "{app} tuned={tuned}");
            assert_eq!(built.layouts, text.layouts, "{app} tuned={tuned}");
            assert_eq!(built.gc, text.gc, "{app} tuned={tuned}");
            assert_eq!(built.backpressure, text.backpressure, "{app} tuned={tuned}");
        }
    }
}

/// Randomized property: an arbitrary chain of typed transformation
/// primitives, evaluated through the builder → bytecode → VM path AND
/// through the tree-walking oracle, must agree with the eagerly
/// transformed `ProcSpace` (an implementation-independent third oracle).
#[test]
fn random_builder_transform_chains_match_procspace_oracle() {
    check(
        "builder transform chains ≡ ProcSpace",
        64,
        |r: &mut Rng| {
            let nodes = *r.choose(&[1usize, 2, 4]);
            let gpus = *r.choose(&[2usize, 4]);
            let steps = r.range(0, 4) as usize;
            let seed = r.next_u64();
            let sx = r.range(2, 8);
            let sy = r.range(2, 8);
            (nodes, gpus, steps, seed, sx, sy)
        },
        |&(nodes, gpus, steps, seed, sx, sy)| {
            let mut desc = MachineDesc::paper_testbed(nodes);
            desc.gpus_per_node = gpus;
            let mut rng = Rng::new(seed);

            // Grow an eagerly evaluated ProcSpace and the identical
            // deferred builder chain side by side.
            let mut space = ProcSpace::machine(&desc, ProcKind::Gpu);
            let mut b = MapperBuilder::new(&desc);
            let mut view = b.machine("m", ProcKind::Gpu);
            for _ in 0..steps {
                match rng.below(5) {
                    0 => {
                        // split a dim by a random divisor
                        let d = rng.below(space.dim() as u64) as usize;
                        let extent = space.size()[d];
                        let divisors: Vec<i64> =
                            (1..=extent).filter(|x| extent % x == 0).collect();
                        let f = *rng.choose(&divisors);
                        space = space.split(d, f).map_err(|e| e.to_string())?;
                        view = view.split(d, f);
                    }
                    1 => {
                        // merge two dims (requires p < q)
                        if space.dim() >= 2 {
                            let p = rng.below(space.dim() as u64 - 1) as usize;
                            let q =
                                p + 1 + rng.below((space.dim() - p - 1) as u64) as usize;
                            space = space.merge(p, q).map_err(|e| e.to_string())?;
                            view = view.merge(p, q);
                        }
                    }
                    2 => {
                        let p = rng.below(space.dim() as u64) as usize;
                        let q = rng.below(space.dim() as u64) as usize;
                        space = space.swap(p, q).map_err(|e| e.to_string())?;
                        view = view.swap(p, q);
                    }
                    3 => {
                        // slice a dim to a random non-empty subrange
                        let d = rng.below(space.dim() as u64) as usize;
                        let extent = space.size()[d];
                        let lo = rng.range(0, extent - 1);
                        let hi = rng.range(lo, extent - 1);
                        space = space.slice(d, lo, hi).map_err(|e| e.to_string())?;
                        view = view.slice(d, lo, hi);
                    }
                    _ => {
                        // decompose (auto_split) with random small targets
                        let d = rng.below(space.dim() as u64) as usize;
                        let k = rng.range(1, 3) as usize;
                        let targets: Vec<i64> =
                            (0..k).map(|_| rng.range(1, 8)).collect();
                        space = space
                            .decompose(d, &Tuple::from(targets.as_slice()))
                            .map_err(|e| e.to_string())?;
                        view = view.auto_split(
                            d,
                            mapple::mapple::build::VExpr::ints(targets.iter().copied()),
                        );
                    }
                }
            }
            let sizes = space.size().clone();
            let dim = space.dim();

            // Mapping function: coordinate j is (linearize(p, s) + j) mod
            // size_j — exercises every dimension of the transformed view.
            let vg = b.view("vg", view);
            b.def_fn("f", |f| {
                let (p, s) = (f.ipoint(), f.ispace());
                let lin = f.bind("lin", mapple::mapple::build::VExpr::linearize(p, s));
                let coords: Vec<mapple::mapple::build::VExpr> = (0..dim)
                    .map(|j| (lin.clone() + (j as i64)) % vg.size_at(j as i64))
                    .collect();
                f.ret(vg.at(coords));
            });
            b.index_task_map("default", "f");
            let spec = b.build()?;

            let ispace = Tuple::from([sx, sy]);
            let dom = Rect::from_extent(&ispace);
            let table = spec.plan_domain("t", &dom).map_err(|e| format!("vm: {e}"))?;
            for p in dom.points() {
                let lin = p.linearize(&ispace);
                let coords: Vec<i64> =
                    (0..dim).map(|j| (lin + j as i64).rem_euclid(sizes[j])).collect();
                let want = space
                    .index(&Tuple::from(coords.as_slice()))
                    .map_err(|e| format!("space oracle: {e}"))?;
                let interp = spec
                    .map_point("t", &p, &ispace)
                    .map_err(|e| format!("interp oracle: {e}"))?;
                if table.get(&p) != Some(want) {
                    return Err(format!(
                        "VM {:?} != ProcSpace {want:?} at {p:?} (shape {sizes:?})",
                        table.get(&p)
                    ));
                }
                if interp != want {
                    return Err(format!(
                        "interp {interp:?} != ProcSpace {want:?} at {p:?} (shape {sizes:?})"
                    ));
                }
            }
            Ok(())
        },
    );
}
