//! Shared helpers for the integration-level test suites: the machine
//! shapes the differential and builder↔text equivalence tests sweep, and
//! real app-instance construction per app name.

// Each test binary compiles this module independently; not every suite
// uses every helper (e.g. exec.rs sweeps its own shape subset).
#![allow(dead_code)]

use mapple::apps;
use mapple::machine::topology::MachineDesc;

/// The machine-shape sweep: {1, 2, 4} nodes × {2, 4} GPUs.
pub fn machine_shapes() -> Vec<MachineDesc> {
    let mut out = Vec::new();
    for nodes in [1usize, 2, 4] {
        for gpus in [2usize, 4] {
            let mut d = MachineDesc::paper_testbed(nodes);
            d.gpus_per_node = gpus;
            out.push(d);
        }
    }
    out
}

/// Build a real instance of one of the nine apps sized for `procs`
/// processors.
pub fn build_app(name: &str, procs: usize) -> apps::AppInstance {
    match name {
        "cannon" => apps::cannon(64, procs),
        "summa" => apps::summa(64, procs),
        "pumma" => apps::pumma(64, procs),
        "johnson" => apps::johnson(64, procs),
        "solomonik" => apps::solomonik(64, procs),
        "cosma" => apps::cosma(64, procs),
        "stencil" => {
            let g = mapple::decompose::decompose(procs as u64, &[256, 256]);
            apps::stencil(&apps::StencilParams {
                x: 256,
                y: 256,
                gx: g.factors[0] as i64,
                gy: g.factors[1] as i64,
                halo: 1,
                steps: 2,
            })
        }
        "circuit" => apps::circuit(&apps::CircuitParams {
            pieces: procs as i64,
            nodes_per_piece: 64,
            wires_per_piece: 128,
            pct_shared: 10,
            loops: 2,
        }),
        "pennant" => apps::pennant(&apps::PennantParams {
            chunks: procs as i64,
            zones_per_chunk: 128,
            cycles: 2,
        }),
        other => panic!("unknown app {other}"),
    }
}
