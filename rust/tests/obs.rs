//! Observability suite: tracing must observe without perturbing.
//!
//! The contract under test (ISSUE 9 / ARCHITECTURE.md "Observability"):
//! running the executor with the obs collector enabled must leave every
//! deterministic result — checksum, placements, byte counters — bitwise
//! identical to a run with it disabled, across all nine apps and both
//! kernel tiers. On top of that: the drained log obeys the merge
//! determinism rule, the Chrome-trace export is well-formed (every event
//! carries the Perfetto-required fields and the export round-trips
//! through the parser), sim and exec breakdowns share one schema with
//! identical row keys, and a chaos recovery emits the documented span
//! sequence (inject round → replan → recovery round, plus the heartbeat
//! death-detection instant on the monitor lane).

mod common;

use common::build_app;
use mapple::apps::{chaos_app, exec_app, run_app_breakdown};
use mapple::bench::{mapper_for, Flavor};
use mapple::chaos::{ChaosOptions, FaultPlan};
use mapple::exec::{self, ExecOptions, KernelMode};
use mapple::machine::topology::MachineDesc;
use mapple::obs::{self, chrome, Cat};
use mapple::util::json::Json;
use std::sync::Mutex;

const APPS: &[&str] = &[
    "cannon", "summa", "pumma", "johnson", "solomonik", "cosma", "stencil", "circuit", "pennant",
];

/// The obs collector is process-global; tests that toggle it serialize.
static OBS_LOCK: Mutex<()> = Mutex::new(());

fn shape(nodes: usize, gpus: usize) -> MachineDesc {
    let mut d = MachineDesc::paper_testbed(nodes);
    d.gpus_per_node = gpus;
    d
}

#[test]
fn tracing_never_changes_results_for_all_nine_apps_and_both_kernel_tiers() {
    let _g = OBS_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let desc = shape(2, 2);
    for app_name in APPS {
        for kernels in [KernelMode::Fast, KernelMode::Naive] {
            let app = build_app(app_name, 4);
            let mapper = mapper_for(&Flavor::Mapple, app_name, &desc);
            let opts = ExecOptions { kernels, ..ExecOptions::default() };
            obs::stop();
            let off = exec_app(&app, mapper.as_ref(), &desc, &opts)
                .unwrap_or_else(|e| panic!("{app_name} {kernels:?} (tracing off): {e}"));
            obs::start();
            let on = exec_app(&app, mapper.as_ref(), &desc, &opts)
                .unwrap_or_else(|e| panic!("{app_name} {kernels:?} (tracing on): {e}"));
            obs::stop();
            let tr = obs::drain();
            assert_eq!(on.exec.checksum, off.exec.checksum, "{app_name} {kernels:?}: checksum");
            assert_eq!(on.exec.placements, off.exec.placements, "{app_name} {kernels:?}");
            assert_eq!(on.exec.intra_bytes, off.exec.intra_bytes, "{app_name} {kernels:?}");
            assert_eq!(on.exec.inter_bytes, off.exec.inter_bytes, "{app_name} {kernels:?}");
            assert!(
                tr.events.iter().any(|e| e.cat == Cat::Kernel),
                "{app_name} {kernels:?}: the traced run recorded kernel spans"
            );
        }
    }
}

#[test]
fn summa_trace_is_chrome_exportable_and_merge_ordered() {
    let _g = OBS_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let desc = shape(2, 2);
    let app = build_app("summa", 4);
    let mapper = mapper_for(&Flavor::Mapple, "summa", &desc);
    obs::start();
    exec_app(&app, mapper.as_ref(), &desc, &ExecOptions::default()).unwrap();
    obs::stop();
    let tr = obs::drain();
    assert!(!tr.events.is_empty());
    // Merge determinism rule: the drained log ascends in ts_ns.
    assert!(tr.events.windows(2).all(|w| w[0].ts_ns <= w[1].ts_ns));
    assert!(tr.events.iter().any(|e| e.cat == Cat::Compile && e.name == "plan_build"));
    assert!(tr.events.iter().any(|e| e.cat == Cat::Kernel && e.detail.is_some()));
    assert!(tr.events.iter().any(|e| e.cat == Cat::Transfer), "2-node summa moves tiles");

    // The export is exactly what `mapple exec --trace` writes: it must
    // round-trip through the parser and carry the Perfetto fields.
    let back = Json::parse(&chrome::to_chrome(&tr).pretty()).unwrap();
    let Some(Json::Arr(evs)) = back.get("traceEvents") else {
        panic!("traceEvents missing: {back:?}");
    };
    assert_eq!(evs.len(), tr.events.len());
    for ev in evs {
        let ph = ev.get("ph").and_then(|p| p.as_str()).unwrap();
        assert!(ph == "X" || ph == "i", "unknown phase {ph}");
        if ph == "X" {
            assert!(ev.get("dur").and_then(|d| d.as_f64()).unwrap() > 0.0);
        }
        for field in ["name", "cat", "pid", "tid", "ts"] {
            assert!(ev.get(field).is_some(), "event missing {field}: {ev:?}");
        }
    }
    let other = back.get("otherData").expect("otherData metadata");
    assert_eq!(other.get("dropped_events").and_then(|d| d.as_f64()), Some(tr.dropped as f64));
}

#[test]
fn sim_and_exec_breakdowns_share_schema_and_row_keys() {
    let _g = OBS_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let desc = shape(2, 2);
    let keys = |j: &Json| match j {
        Json::Obj(m) => m.keys().cloned().collect::<Vec<_>>(),
        other => panic!("expected object, got {other:?}"),
    };
    for app_name in ["summa", "stencil", "pennant"] {
        let app = build_app(app_name, 4);
        let mapper = mapper_for(&Flavor::Mapple, app_name, &desc);
        let (_, sim_bd) = run_app_breakdown(&app, mapper.as_ref(), &desc).unwrap();
        obs::start();
        let out = exec_app(&app, mapper.as_ref(), &desc, &ExecOptions::default()).unwrap();
        obs::stop();
        let exec_bd = exec::breakdown(&out.exec, &obs::drain());
        // Row keys identical by construction (both derive from launch
        // names) — the property that makes the two views diff row-for-row.
        assert_eq!(sim_bd.row_keys(), exec_bd.row_keys(), "{app_name}: row keys");
        let (sj, ej) = (sim_bd.to_json(), exec_bd.to_json());
        assert_eq!(keys(&sj), keys(&ej), "{app_name}: top-level schema");
        for fam in sim_bd.row_keys() {
            let srow = sj.get("families").unwrap().get(fam).unwrap();
            let erow = ej.get("families").unwrap().get(fam).unwrap();
            assert_eq!(keys(srow), keys(erow), "{app_name}/{fam}: row schema");
            // Both sources count the same task population per family.
            assert_eq!(srow.get("tasks"), erow.get("tasks"), "{app_name}/{fam}: tasks");
        }
        // The exec byte columns reconcile with the run's own counters.
        let intra: u64 = exec_bd.rows.values().map(|r| r.intra_bytes).sum();
        let inter: u64 = exec_bd.rows.values().map(|r| r.inter_bytes).sum();
        assert_eq!(intra, out.exec.intra_bytes, "{app_name}: intra bytes reconcile");
        assert_eq!(inter, out.exec.inter_bytes, "{app_name}: inter bytes reconcile");
        // And the measured times actually landed in the rows.
        assert!(exec_bd.rows.values().any(|r| r.compute_ns > 0.0), "{app_name}: compute");
    }
}

#[test]
fn chaos_recovery_emits_well_formed_recovery_spans() {
    let _g = OBS_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let desc = shape(2, 2);
    let opts = ChaosOptions {
        exec: ExecOptions::default(),
        faults: FaultPlan::parse("kill:1@2").unwrap(),
        fault_seed: 7,
        heartbeat_us: 200,
        miss_threshold: 10,
    };
    let app = build_app("cannon", 4);
    let mapper = mapper_for(&Flavor::Mapple, "cannon", &desc);
    obs::start();
    let out = chaos_app(&app, mapper.as_ref(), &desc, &opts).unwrap();
    obs::stop();
    let tr = obs::drain();
    assert_eq!(out.chaos.report.rounds, 2, "kill must force a recovery round");

    let recov: Vec<_> = tr.events.iter().filter(|e| e.cat == Cat::Recovery).collect();
    let named = |n: &str, d: Option<&str>| {
        recov.iter().find(|e| e.name == n && e.detail.as_deref() == d)
    };
    let inject = named("round", Some("inject")).expect("inject-round span");
    let replan = named("replan", None).expect("replan span");
    let recover = named("round", Some("recover")).expect("recovery-round span");
    for e in [inject, replan, recover] {
        assert!(e.dur_ns >= 1, "recovery spans carry real durations");
        assert_eq!((e.node, e.lane), (0, 0), "recovery is orchestrated from lane (0, 0)");
    }
    // The documented sequence: inject round, then replan, then recovery.
    assert!(inject.ts_ns <= replan.ts_ns && replan.ts_ns <= recover.ts_ns);
    // Span args agree with the deterministic chaos report.
    assert_eq!(inject.args[0], ("kills", 1));
    let r = &out.chaos.report;
    assert_eq!(replan.args[0], ("rerun", r.rerun_tasks as i64));
    assert_eq!(recover.args[0], ("rerun", r.rerun_tasks as i64));

    // Heartbeat detection fired on the monitor service lane (902) for
    // the killed node, and the degraded machine purged the plan cache.
    let death = tr
        .events
        .iter()
        .find(|e| e.cat == Cat::Heartbeat && e.name == "death_detected")
        .expect("death_detected instant");
    assert_eq!((death.node, death.lane), (1, 902));
    assert_eq!(death.args[0], ("node", 1));
    assert_eq!(death.dur_ns, 0, "detection is an instant, not a span");
    assert!(tr.events.iter().any(|e| e.cat == Cat::Cache && e.name == "invalidate_machine"));

    // The rollup counters (what the serve `stats` op surfaces) saw the
    // same activity the drained log carries.
    let rollup = obs::rollup_json();
    let count = |cat: &str| {
        rollup.get("recorded").and_then(|r| r.get(cat)).and_then(|n| n.as_f64()).unwrap()
    };
    assert!(count("recovery") >= 3.0);
    assert!(count("heartbeat") >= 1.0);
    assert!(count("kernel") > 0.0);
}
