//! Three-way differential suite: closure-compiled plans vs the bytecode
//! VM vs the tree-walking interpreter.
//!
//! PR 2 proved VM ≡ interpreter; this suite adds the third tier
//! (`mapple::compile` — the default evaluation path behind
//! `MappingPlan::eval_domain`) and proves all three agree:
//!
//!   compiled placement(point) == VM placement(point) == interp placement(point)
//!
//! for all nine apps' mappers (baseline and tuned) across the machine
//! shapes, for the randomized language-coverage corpus, and on error
//! outcomes. Whole `PlacementTable`s are compared (lo/extent/procs), not
//! just spot points, and every comparison asserts the function really is
//! on the compiled tier so the test cannot silently degrade into VM≡VM.

mod common;

use common::{build_app, machine_shapes};
use mapple::apps::mappers;
use mapple::machine::point::{Rect, Tuple};
use mapple::machine::topology::MachineDesc;
use mapple::mapple::MapperSpec;
use mapple::util::prng::Rng;
use mapple::util::proptest::check;

const APPS: &[&str] = &[
    "cannon", "summa", "pumma", "johnson", "solomonik", "cosma", "stencil", "circuit", "pennant",
];

/// All 18 shipped mappers (base + tuned × nine apps) × machine shapes:
/// the compiled tier and the VM produce identical `PlacementTable`s, and
/// both match the per-point interpreter oracle.
#[test]
fn compiled_vm_and_interp_agree_for_all_eighteen_mappers() {
    for desc in machine_shapes() {
        let procs = desc.nodes * desc.gpus_per_node;
        for app_name in APPS {
            let sources = [
                ("base", mappers::mapple_source(app_name).unwrap()),
                ("tuned", mappers::tuned_source(app_name).unwrap()),
            ];
            for (flavor, src) in sources {
                let spec = MapperSpec::compile(src, &desc)
                    .unwrap_or_else(|e| panic!("{app_name} {flavor}: {e}"));
                let app = build_app(app_name, procs);
                for launch in &app.launches {
                    let func = spec
                        .mapping_fn(&launch.name)
                        .unwrap_or_else(|| panic!("{app_name}: no mapping for {}", launch.name));
                    assert!(
                        spec.plan.compiled_for(func),
                        "{app_name} {flavor}: '{func}' not on the compiled tier"
                    );
                    let ctx = format!(
                        "{app_name} {flavor} {} ({}n×{}g)",
                        launch.name, desc.nodes, desc.gpus_per_node
                    );
                    let compiled = spec
                        .plan
                        .eval_domain(func, &launch.domain)
                        .unwrap_or_else(|e| panic!("{ctx} compiled: {e}"));
                    let vm = spec
                        .plan
                        .eval_domain_vm(func, &launch.domain)
                        .unwrap_or_else(|e| panic!("{ctx} vm: {e}"));
                    assert_eq!(compiled, vm, "{ctx}: compiled table != VM table");
                    let ispace = launch.domain.extent();
                    for p in launch.domain.points() {
                        let oracle = spec
                            .map_point(&launch.name, &p, &ispace)
                            .unwrap_or_else(|e| panic!("{ctx} oracle: {e}"));
                        assert_eq!(compiled.get(&p), Some(oracle), "{ctx} point {p:?}");
                    }
                }
            }
        }
    }
}

/// Single-point requests route through the compiled tier too (the
/// `mapple serve` / `Mapper::map_task` path): `eval_point` ≡
/// `eval_point_vm` ≡ interpreter for all 18 shipped mappers, over every
/// point of every launch domain.
#[test]
fn compiled_eval_point_matches_vm_and_interp() {
    for desc in machine_shapes() {
        let procs = desc.nodes * desc.gpus_per_node;
        for app_name in APPS {
            let sources = [
                ("base", mappers::mapple_source(app_name).unwrap()),
                ("tuned", mappers::tuned_source(app_name).unwrap()),
            ];
            for (flavor, src) in sources {
                let spec = MapperSpec::compile(src, &desc)
                    .unwrap_or_else(|e| panic!("{app_name} {flavor}: {e}"));
                let app = build_app(app_name, procs);
                for launch in &app.launches {
                    let func = spec
                        .mapping_fn(&launch.name)
                        .unwrap_or_else(|| panic!("{app_name}: no mapping for {}", launch.name));
                    assert!(
                        spec.plan.compiled_for(func),
                        "{app_name} {flavor}: '{func}' not on the compiled tier"
                    );
                    let ctx = format!(
                        "{app_name} {flavor} {} ({}n×{}g)",
                        launch.name, desc.nodes, desc.gpus_per_node
                    );
                    let ispace = launch.domain.extent();
                    for p in launch.domain.points() {
                        let compiled = spec
                            .plan
                            .eval_point(func, &p, &ispace)
                            .unwrap_or_else(|e| panic!("{ctx} compiled: {e}"));
                        let vm = spec
                            .plan
                            .eval_point_vm(func, &p, &ispace)
                            .unwrap_or_else(|e| panic!("{ctx} vm: {e}"));
                        assert_eq!(compiled, vm, "{ctx} point {p:?}: compiled != VM");
                        let oracle = spec
                            .map_point(&launch.name, &p, &ispace)
                            .unwrap_or_else(|e| panic!("{ctx} oracle: {e}"));
                        assert_eq!(compiled, oracle, "{ctx} point {p:?}: compiled != interp");
                    }
                }
            }
        }
    }
}

/// The same language-coverage corpus the VM differential randomizes over
/// (ternaries, and/or chains, builtins, negative indexing, helper calls,
/// hoisted locals, splat indexing) — three ways.
const COVERAGE_MAPPERS: &[&str] = &[
    "m = Machine(GPU)\n\
     m1 = m.merge(0, 1)\n\
     def f(Tuple p, Tuple s):\n    \
         g = s[0] > s[1] ? s[0] : s[1]\n    \
         return m1[(p[0] * g + p[1]) % m1.size[0]]\n",
    "m = Machine(GPU)\n\
     def f(Tuple p, Tuple s):\n    \
         if p[0] == 0 and p[1] == 0:\n        \
             return m[0, 0]\n    \
         elif p[0] == 0 or p[1] == 0:\n        \
             return m[p[0] % m.size[0], 0]\n    \
         else:\n        \
             return m[p[0] % m.size[0], p[1] % m.size[1]]\n",
    "m = Machine(GPU)\n\
     def helper(Tuple p, Tuple s):\n    \
         return min(p) + max(s) + len(p) + abs(p[0] - s[1]) + prod(p + 1)\n\
     def f(Tuple p, Tuple s):\n    \
         v = helper(p, s)\n    \
         return m[v % m.size[0], v % m.size[1]]\n",
    "m = Machine(GPU)\n\
     def f(Tuple p, Tuple s):\n    \
         lin = linearize(p, s)\n    \
         tail = s[1:]\n    \
         return m[(lin + tail[0] + p[-1]) % m.size[0], 0]\n",
    "m = Machine(GPU)\n\
     def f(Tuple p, Tuple s):\n    \
         x = s[0] + s[1]\n    \
         x = x * 3 + p[0] * 2 + p[1]\n    \
         return m[x % m.size[0], x % m.size[1]]\n",
    "m = Machine(GPU)\n\
     def f(Tuple p, Tuple s):\n    \
         m2 = m.swap(0, 1)\n    \
         idx = tuple(p[i] % m2.size[i] for i in (0, 1))\n    \
         return m2[*idx]\n",
];

#[test]
fn compiled_matches_vm_and_interp_on_language_coverage_corpus() {
    check(
        "compiled ≡ vm ≡ interp on coverage corpus",
        96,
        |r: &mut Rng| {
            let which = r.range(0, COVERAGE_MAPPERS.len() as i64 - 1) as usize;
            let nodes = *r.choose(&[1usize, 2, 4]);
            let gpus = *r.choose(&[2usize, 4]);
            let sx = r.range(2, 9);
            let sy = r.range(2, 9);
            (which, nodes, gpus, sx, sy)
        },
        |&(which, nodes, gpus, sx, sy)| {
            let mut desc = MachineDesc::paper_testbed(nodes);
            desc.gpus_per_node = gpus;
            let src = COVERAGE_MAPPERS[which];
            let spec = MapperSpec::compile(src, &desc).map_err(|e| e.to_string())?;
            if !spec.plan.compiled_for("f") {
                return Err(format!("corpus mapper {which} did not reach the compiled tier"));
            }
            let ispace = Tuple::from([sx, sy]);
            let dom = Rect::from_extent(&ispace);
            let compiled = spec.plan.eval_domain("f", &dom).map_err(|e| e.to_string())?;
            let vm = spec.plan.eval_domain_vm("f", &dom).map_err(|e| format!("vm: {e}"))?;
            if compiled != vm {
                return Err(format!(
                    "mapper {which} ({nodes}n×{gpus}g, ispace {ispace:?}): compiled table != VM table"
                ));
            }
            for p in dom.points() {
                let oracle = spec
                    .interp
                    .map_point("f", &p, &ispace)
                    .map_err(|e| format!("oracle: {e}"))?;
                if compiled.get(&p) != Some(oracle) {
                    return Err(format!(
                        "mapper {which} ({nodes}n×{gpus}g, ispace {ispace:?}): compiled {:?} != interp {oracle:?} at {p:?}",
                        compiled.get(&p)
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Error-path agreement: when the interpreter rejects a program at
/// runtime, both the compiled tier and the VM must reject it too
/// (messages may differ; outcomes must agree).
#[test]
fn compiled_vm_and_interp_agree_on_failures() {
    let desc = MachineDesc::paper_testbed(2);
    let cases = [
        // non-processor return
        "m = Machine(GPU)\ndef f(Tuple p, Tuple s):\n    return 7\n",
        // division by zero
        "m = Machine(GPU)\ndef f(Tuple p, Tuple s):\n    return m[p[0] / 0, 0]\n",
        // out-of-bounds space index
        "m = Machine(GPU)\ndef f(Tuple p, Tuple s):\n    return m[99, 99]\n",
        // unbounded recursion
        "m = Machine(GPU)\ndef f(Tuple p, Tuple s):\n    return f(p, s)\n",
    ];
    let ispace = Tuple::from([2, 2]);
    let dom = Rect::from_extent(&ispace);
    for src in cases {
        let spec = MapperSpec::compile(src, &desc).unwrap();
        assert!(spec.plan.compiled_for("f"), "{src}");
        assert!(spec.plan.eval_domain("f", &dom).is_err(), "compiled accepted: {src}");
        assert!(spec.plan.eval_domain_vm("f", &dom).is_err(), "VM accepted: {src}");
        assert!(
            spec.interp.map_point("f", &Tuple::from([0, 0]), &ispace).is_err(),
            "interp accepted: {src}"
        );
    }
}

/// Directive tables are independent of the evaluation tier: the same
/// `.mpl` source compiled twice yields identical policy tables, and the
/// placement path through the public `MapperSpec` surface (which now
/// routes through the compiled tier) matches the interpreter.
#[test]
fn directive_tables_and_public_surface_are_tier_independent() {
    let desc = MachineDesc::paper_testbed(2);
    for app_name in APPS {
        let src = mappers::tuned_source(app_name).unwrap();
        let a = MapperSpec::compile(src, &desc).unwrap();
        let b = MapperSpec::compile(src, &desc).unwrap();
        assert_eq!(a.index_task_maps, b.index_task_maps, "{app_name}");
        assert_eq!(a.task_maps, b.task_maps, "{app_name}");
        assert_eq!(a.regions, b.regions, "{app_name}");
        assert_eq!(a.layouts, b.layouts, "{app_name}");
        assert_eq!(a.gc, b.gc, "{app_name}");
        assert_eq!(a.backpressure, b.backpressure, "{app_name}");
        let app = build_app(app_name, desc.nodes * desc.gpus_per_node);
        for launch in &app.launches {
            let ispace = launch.domain.extent();
            let table = a.plan_domain(&launch.name, &launch.domain).unwrap();
            for p in launch.domain.points() {
                let oracle = a.map_point(&launch.name, &p, &ispace).unwrap();
                assert_eq!(table.get(&p), Some(oracle), "{app_name}/{} {p:?}", launch.name);
            }
        }
    }
}
