//! Table 1 — lines-of-code comparison: Mapple mappers vs the low-level
//! expert mappers (non-blank, non-comment lines, the paper's counting
//! rule). Also times mapper compilation to show DSL overhead is
//! negligible.
//!
//! Run: `cargo bench --bench table1_loc`

use mapple::apps::mappers::MAPPER_SOURCES;
use mapple::bench::write_report;
use mapple::machine::topology::MachineDesc;
use mapple::mapple::MapperSpec;
use mapple::util::bench::{fmt_time, Bencher};
use mapple::util::json::Json;
use mapple::util::loc::{count_c_like, count_dsl};
use mapple::util::table::Table;

/// Extract the low-level source attributable to one expert mapper: the
/// file's shared helper prelude (before the first section banner) plus
/// that mapper's own banner-delimited section — mirroring how each of the
/// paper's C++ mappers carries its own copy of the helper boilerplate.
fn expert_section(file: &str, marker: &str) -> String {
    let banner = "// ======";
    let mut sections: Vec<(Option<String>, String)> = Vec::new();
    let mut current_name: Option<String> = None;
    let mut current = String::new();
    let mut lines = file.lines().peekable();
    while let Some(line) = lines.next() {
        if line.starts_with(banner) {
            // banner line, then the title line, then another banner line
            let title = lines.next().unwrap_or("").trim_start_matches("//").trim().to_string();
            let _ = lines.next(); // closing banner
            sections.push((current_name.take(), std::mem::take(&mut current)));
            current_name = Some(title);
            continue;
        }
        current.push_str(line);
        current.push('\n');
    }
    sections.push((current_name.take(), std::mem::take(&mut current)));
    let prelude = sections
        .iter()
        .find(|(n, _)| n.is_none())
        .map(|(_, s)| s.clone())
        .unwrap_or_default();
    let body = sections
        .iter()
        .find(|(n, _)| n.as_deref().map(|t| t.to_lowercase().contains(marker)).unwrap_or(false))
        .map(|(_, s)| s.clone())
        .unwrap_or_else(|| panic!("no section for '{marker}'"));
    // strip the trailing #[cfg(test)] module from the last section
    let body = body.split("#[cfg(test)]").next().unwrap().to_string();
    format!("{prelude}{body}")
}

fn expert_file(app: &str) -> &'static str {
    match app {
        "cannon" | "summa" | "pumma" => include_str!("../src/mapper/expert/matmul2d.rs"),
        "johnson" | "solomonik" | "cosma" => include_str!("../src/mapper/expert/matmul3d.rs"),
        _ => include_str!("../src/mapper/expert/science.rs"),
    }
}

/// The expert placement logic itself now lives in the shared builder
/// reconstructions (`apps/builder_mappers.rs`); the per-app files keep
/// only the policy wrappers. Attribute each app an equal share of that
/// construction code so the low-level column still counts the code that
/// actually produces the mapping.
fn builder_share_loc(num_apps: usize) -> usize {
    let src = include_str!("../src/apps/builder_mappers.rs");
    let body = src.split("#[cfg(test)]").next().unwrap();
    count_c_like(body) / num_apps
}

fn marker(app: &str) -> &'static str {
    match app {
        "cannon" => "cannon",
        "summa" => "summa",
        "pumma" => "pumma",
        "johnson" => "johnson",
        "solomonik" => "solomonik",
        "cosma" => "cosma",
        "stencil" => "stencil",
        "circuit" => "circuit",
        "pennant" => "pennant",
        _ => unreachable!(),
    }
}

fn main() {
    println!("Table 1: lines of code — Mapple DSL vs low-level expert mappers\n");
    let order = ["circuit", "stencil", "pennant", "cannon", "summa", "pumma", "johnson", "solomonik", "cosma"];
    let mut t = Table::new(["#", "Application", "LoC low-level", "LoC Mapple", "Reduction"]);
    let mut total_low = 0usize;
    let mut total_mpl = 0usize;
    let mut rows = Vec::new();
    let builder_share = builder_share_loc(order.len());
    for (i, app) in order.iter().enumerate() {
        let mpl = MAPPER_SOURCES.iter().find(|(a, _, _)| a == app).unwrap().1;
        let mpl_loc = count_dsl(mpl);
        let low = expert_section(expert_file(app), marker(app));
        let low_loc = count_c_like(&low) + builder_share;
        total_low += low_loc;
        total_mpl += mpl_loc;
        t.row([
            format!("{}", i + 1),
            app.to_string(),
            format!("{low_loc}"),
            format!("{mpl_loc}"),
            format!("{:.1}x", low_loc as f64 / mpl_loc as f64),
        ]);
        rows.push(Json::obj(vec![
            ("app", Json::Str(app.to_string())),
            ("low_level_loc", Json::Num(low_loc as f64)),
            ("mapple_loc", Json::Num(mpl_loc as f64)),
        ]));
    }
    let avg = total_low as f64 / total_mpl as f64;
    let napps = order.len() as f64;
    t.row([
        "".into(),
        "Average".into(),
        format!("{:.0}", total_low as f64 / napps),
        format!("{:.0}", total_mpl as f64 / napps),
        format!("{avg:.1}x"),
    ]);
    print!("{}", t.render());
    println!("\npaper: 406 vs 29 average → 14x reduction. Since the experts were rebuilt on");
    println!("the typed mapple::build API (sharing the transform/decompose machinery), the");
    println!("low-level column counts each app's policy wrapper plus its share of the");
    println!("builder construction code — the gap now measures construction-API verbosity");
    println!("rather than reimplemented boilerplate; shape check: low-level > Mapple remains.\n");

    // DSL compile cost (the paper reports no observable overhead).
    let desc = MachineDesc::paper_testbed(2);
    let b = Bencher::default();
    let src = MAPPER_SOURCES[0].1;
    let m = b.run("compile cannon.mpl", || MapperSpec::compile(src, &desc).unwrap());
    println!("mapper compile time: {}", m.summary());
    println!("(one-time cost per program; mapping itself is table-cached)");

    write_report(
        "table1_loc",
        &Json::obj(vec![
            ("rows", Json::Arr(rows)),
            ("avg_reduction", Json::Num(avg)),
            ("compile_median_s", Json::Num(m.median())),
        ]),
    );
    assert!(avg > 1.5, "LoC reduction collapsed — check the counters");
}
