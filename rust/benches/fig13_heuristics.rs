//! Figure 13 — algorithm-specified mapping vs runtime heuristics for
//! Cannon's, PUMMA, and SUMMA: throughput per node across machine sizes,
//! with the heuristic mapper suffering large slowdowns (paper: up to
//! 3.5× at 1 node) and OOM at 32 GPUs for PUMMA/SUMMA.
//!
//! Run: `cargo bench --bench fig13_heuristics`

use mapple::apps;
use mapple::bench::{mapper_for, run, write_report, Flavor};
use mapple::machine::topology::MachineDesc;
use mapple::util::json::Json;
use mapple::util::table::Table;

fn build(app: &str, n: i64, procs: usize) -> apps::AppInstance {
    match app {
        "cannon" => apps::cannon(n, procs),
        "pumma" => apps::pumma(n, procs),
        "summa" => apps::summa(n, procs),
        _ => unreachable!(),
    }
}

fn main() {
    println!("Figure 13: algorithm specification vs runtime heuristics\n");
    let gpu_counts = [4usize, 8, 16, 32];
    let mut report_rows = Vec::new();
    for app in ["cannon", "pumma", "summa"] {
        println!("--- {app} ---");
        let mut t = Table::new([
            "GPUs",
            "nodes",
            "N",
            "spec GFLOP/s/node",
            "heur GFLOP/s/node",
            "slowdown",
            "spec peak FB",
            "heur peak FB",
        ]);
        for &gpus in &gpu_counts {
            let nodes = (gpus / 4).max(1);
            let desc = MachineDesc::paper_testbed(nodes);
            // weak scaling sized so that the wasteful heuristic placement
            // overruns a 16 GiB framebuffer at the 32-GPU point
            let n = (18.0 * 1024.0 * (gpus as f64 / 4.0).sqrt()).round() as i64 / 1024 * 1024;
            let app_inst = build(app, n, gpus);
            let spec_mapper = mapper_for(&Flavor::Mapple, app, &desc);
            let heur_mapper = mapper_for(&Flavor::Heuristic, app, &desc);
            let spec = run(&app_inst, spec_mapper.as_ref(), &desc).unwrap();
            assert!(spec.oom.is_none(), "{app}: the intended mapping must fit");
            let heur = run(&app_inst, heur_mapper.as_ref(), &desc).unwrap();
            let spec_tp = spec.throughput_per_node(nodes) / 1e9;
            let (heur_tp, slowdown, oom) = if heur.oom.is_some() {
                (0.0, f64::NAN, true)
            } else {
                let tp = heur.throughput_per_node(nodes) / 1e9;
                (tp, spec_tp / tp, false)
            };
            t.row([
                format!("{gpus}"),
                format!("{nodes}"),
                format!("{n}"),
                format!("{spec_tp:.1}"),
                if oom { "OOM".into() } else { format!("{heur_tp:.1}") },
                if oom { "—".into() } else { format!("{slowdown:.2}x") },
                format!("{:.1} GiB", spec.peak_fbmem as f64 / (1u64 << 30) as f64),
                format!("{:.1} GiB", heur.peak_fbmem as f64 / (1u64 << 30) as f64),
            ]);
            report_rows.push(Json::obj(vec![
                ("app", Json::Str(app.to_string())),
                ("gpus", Json::Num(gpus as f64)),
                ("spec_tp", Json::Num(spec_tp)),
                ("heur_tp", Json::Num(heur_tp)),
                ("heur_oom", Json::Bool(oom)),
            ]));
        }
        print!("{}", t.render());
        println!();
    }
    println!(
        "shape check vs paper: the algorithm-specified mapping wins everywhere;\n\
         slowdowns grow at small node counts; heuristic mapping inflates peak\n\
         framebuffer usage (paper: OOM on 32-GPU PUMMA/SUMMA runs)."
    );
    write_report("fig13_heuristics", &Json::obj(vec![("rows", Json::Arr(report_rows))]));
}
