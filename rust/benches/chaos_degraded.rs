//! chaos_degraded — degraded-mode wall-clock report for the chaos
//! engine: for a few representative apps on a 2-node machine, compare
//!
//!   fault_free_s   plain exec (the failure-free baseline `run_chaos`
//!                  verifies against)
//!   idle_chaos_s   chaos path with an *empty* fault plan — measures
//!                  what the chaos plumbing costs when nothing fails
//!                  (the heartbeat/retention machinery only arms itself
//!                  when kills are scheduled, so this should track the
//!                  baseline closely)
//!   degraded_s     a mid-run node kill, detected by heartbeat and
//!                  recovered by replanning the lost suffix onto the
//!                  survivor
//!
//! Every chaos run is checksum-verified bitwise against the failure-free
//! oracle inside `run_chaos`, so the timings here are for *correct*
//! recoveries only. Report-only: the numbers land in
//! `bench_reports/chaos_degraded.json`; correctness is gated by
//! `tests/chaos.rs`, and fault-free overhead by `wallclock_gate`.
//!
//! Run: `cargo bench --bench chaos_degraded`

use mapple::bench::{build_bench_app, mapper_for, run_chaos, write_report, Flavor};
use mapple::chaos::{ChaosOptions, FaultPlan};
use mapple::machine::topology::MachineDesc;
use mapple::serve::proto::digest_hex;
use mapple::util::json::Json;

const APPS: &[&str] = &["cannon", "stencil", "circuit"];
const KILL_SPEC: &str = "kill:1@2";
const TRIALS: usize = 3;

fn main() {
    let desc = MachineDesc::paper_testbed(2);
    println!("== chaos engine: degraded-mode wall-clock (2 nodes, spec `{KILL_SPEC}`) ==");
    let mut rows = Vec::new();
    for &app_name in APPS {
        let app = build_bench_app(app_name, &desc);
        let mapper = mapper_for(&Flavor::Mapple, app_name, &desc);
        let idle_opts = ChaosOptions::default();
        let kill_opts = ChaosOptions {
            faults: FaultPlan::parse(KILL_SPEC).expect("bench kill spec parses"),
            ..ChaosOptions::default()
        };
        let mut fault_free = f64::INFINITY;
        let mut idle = f64::INFINITY;
        let mut degraded = f64::INFINITY;
        let mut kill_report = None;
        for _ in 0..TRIALS {
            let calm = run_chaos(&app, mapper.as_ref(), &desc, &idle_opts)
                .unwrap_or_else(|e| panic!("{app_name} (no faults): {e}"));
            assert_eq!(calm.chaos.report.rounds, 1, "{app_name}: empty plan must not replan");
            fault_free = fault_free.min(calm.baseline.wall_seconds);
            idle = idle.min(calm.chaos.result.wall_seconds);

            let hurt = run_chaos(&app, mapper.as_ref(), &desc, &kill_opts)
                .unwrap_or_else(|e| panic!("{app_name} ({KILL_SPEC}): {e}"));
            assert_eq!(hurt.chaos.report.killed.len(), 1, "{app_name}: one node dies");
            assert_eq!(hurt.chaos.report.survivors, 1, "{app_name}: one node survives");
            fault_free = fault_free.min(hurt.baseline.wall_seconds);
            degraded = degraded.min(hurt.chaos.result.wall_seconds);
            kill_report = Some(hurt.chaos.report);
        }
        let r = kill_report.unwrap();
        println!(
            "  {app_name:10}  fault-free {fault_free:8.3}s   idle-chaos {idle:8.3}s   \
             killed {degraded:8.3}s ({:.2}x)   rerun {} replay {} refetch {}",
            degraded / fault_free,
            r.rerun_tasks,
            r.replayed_tasks,
            r.refetched_tiles,
        );
        rows.push(Json::obj(vec![
            ("app", Json::Str(app_name.to_string())),
            ("fault_free_s", Json::Num(fault_free)),
            ("idle_chaos_s", Json::Num(idle)),
            ("degraded_s", Json::Num(degraded)),
            ("idle_overhead", Json::Num(idle / fault_free)),
            ("degraded_slowdown", Json::Num(degraded / fault_free)),
            ("rerun_tasks", Json::Num(r.rerun_tasks as f64)),
            ("replayed_tasks", Json::Num(r.replayed_tasks as f64)),
            ("refetched_tiles", Json::Num(r.refetched_tiles as f64)),
            ("recovery_inter_kib", Json::Num((r.recovery_inter_bytes >> 10) as f64)),
            ("report_digest", Json::Str(digest_hex(r.digest()))),
        ]));
    }
    let report = Json::obj(vec![
        ("spec", Json::Str(KILL_SPEC.to_string())),
        ("trials", Json::Num(TRIALS as f64)),
        ("apps", Json::arr(rows)),
    ]);
    write_report("chaos_degraded", &report);
}
