//! Wall-clock soft gate for the executor's kernel tier: the blocked,
//! buffer-pooled kernels (`KernelMode::Fast`, the default) must beat the
//! naive reference kernels (`KernelMode::Naive`) by ≥2x geomean across
//! the six matmul apps on a 2-node machine — at bitwise-identical
//! checksums and identical byte accounting, so the speed can only come
//! from how the same arithmetic is scheduled, never from doing different
//! arithmetic.
//!
//! Like `perf_hotpath`, each mode takes the **best (minimum) wall-clock
//! over a few trials**: CI-runner noise only ever slows a trial down, so
//! the min is the robust estimate and a single descheduled sample cannot
//! fail the job spuriously. The gate is on the geomean across apps
//! rather than per-app, which tolerates one app with an unlucky
//! tile shape without letting a real regression through.
//!
//! Run: `cargo bench --bench wallclock_gate`

use mapple::bench::{mapper_for, run_exec, write_report, Flavor};
use mapple::exec::{ExecOptions, KernelMode};
use mapple::machine::topology::MachineDesc;
use mapple::serve::proto::digest_hex;
use mapple::util::json::Json;
use mapple::{apps, exec::ExecResult};

const MATMUL_APPS: &[&str] = &["cannon", "summa", "pumma", "johnson", "solomonik", "cosma"];
const N: i64 = 512;
const TRIALS: usize = 3;

fn best_of(app_name: &str, mode: KernelMode) -> ExecResult {
    let desc = MachineDesc::paper_testbed(2);
    let procs = desc.nodes * desc.gpus_per_node;
    let app = match app_name {
        "cannon" => apps::cannon(N, procs),
        "summa" => apps::summa(N, procs),
        "pumma" => apps::pumma(N, procs),
        "johnson" => apps::johnson(N, procs),
        "solomonik" => apps::solomonik(N, procs),
        "cosma" => apps::cosma(N, procs),
        other => panic!("unknown matmul app {other}"),
    };
    let mapper = mapper_for(&Flavor::Mapple, app_name, &desc);
    let opts = ExecOptions { kernels: mode, ..ExecOptions::default() };
    let mut best: Option<ExecResult> = None;
    for _ in 0..TRIALS {
        let r = run_exec(&app, mapper.as_ref(), &desc, &opts)
            .unwrap_or_else(|e| panic!("{app_name} ({mode:?}): {e}"));
        if best.as_ref().map(|b| r.wall_seconds < b.wall_seconds).unwrap_or(true) {
            best = Some(r);
        }
    }
    best.unwrap()
}

fn main() {
    println!("== exec wall-clock: blocked/pooled kernels vs naive (N={N}, 2 nodes) ==");
    let mut rows = Vec::new();
    let mut log_sum = 0.0f64;
    for app in MATMUL_APPS {
        let naive = best_of(app, KernelMode::Naive);
        let fast = best_of(app, KernelMode::Fast);
        // Representation independence: the kernel tier may only change
        // how fast the answer arrives, never the answer or the traffic.
        assert_eq!(fast.checksum, naive.checksum, "{app}: checksum drifted between kernel modes");
        assert_eq!(fast.intra_bytes, naive.intra_bytes, "{app}: intra-node bytes drifted");
        assert_eq!(fast.inter_bytes, naive.inter_bytes, "{app}: inter-node bytes drifted");
        let speedup = naive.wall_seconds / fast.wall_seconds;
        log_sum += speedup.ln();
        println!(
            "  {app:10}  naive {:8.3}s   fast {:8.3}s   {speedup:5.2}x   checksum {:016x}",
            naive.wall_seconds, fast.wall_seconds, fast.checksum
        );
        rows.push(Json::obj(vec![
            ("app", Json::Str(app.to_string())),
            ("naive_seconds", Json::Num(naive.wall_seconds)),
            ("fast_seconds", Json::Num(fast.wall_seconds)),
            ("speedup", Json::Num(speedup)),
            ("checksum", Json::Str(digest_hex(fast.checksum))),
        ]));
    }
    let geomean = (log_sum / MATMUL_APPS.len() as f64).exp();
    println!(
        "  geomean fast/naive speedup: {geomean:.2}x  [{}]",
        if geomean >= 2.0 { "PASS ≥2x" } else { "FAIL <2x" }
    );
    let report = Json::obj(vec![
        ("n", Json::Num(N as f64)),
        ("trials", Json::Num(TRIALS as f64)),
        ("geomean_speedup", Json::Num(geomean)),
        ("apps", Json::arr(rows)),
    ]);
    write_report("wallclock_gate", &report);
    assert!(
        geomean >= 2.0,
        "blocked/pooled kernels must be ≥2x naive (geomean over the six matmul \
         apps, best of {TRIALS} trials per mode; got {geomean:.2}x)"
    );
}
