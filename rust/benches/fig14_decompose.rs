//! Figures 14–17 + Table 3 — the decompose study: end-to-end stencil
//! performance of the decompose-chosen processor grid vs Algorithm 1's
//! greedy grid over the full Table 3 parameter space:
//!
//!   aspect ratio   1:1, 1:2, 1:4, 1:8, 1:16, 1:32
//!   area per node  1e6, 1e7, 1e8, 2e8, 4e8 elements
//!   GPUs           4, 8, 16, 32, 64, 128
//!
//! = 180 configurations. Reports the improvement distribution (Fig 14)
//! and geomean improvement vs aspect ratio (Fig 15), area per node
//! (Fig 16), and machine size (Fig 17).
//!
//! Run: `cargo bench --bench fig14_decompose`

use mapple::apps::{self, mappers};
use mapple::bench::write_report;
use mapple::decompose::{decompose, greedy_grid};
use mapple::machine::topology::MachineDesc;
use mapple::mapper::MappleMapper;
use mapple::mapple::MapperSpec;
use mapple::util::json::Json;
use mapple::util::stats::{geomean, histogram, max as fmax, min as fmin};
use mapple::util::table::Table;

struct Config {
    aspect: i64,
    area_per_node: f64,
    gpus: usize,
}

/// Round v to the closest multiple of m (at least m).
fn round_to(v: f64, m: i64) -> i64 {
    ((v / m as f64).round() as i64).max(1) * m
}

fn run_stencil(desc: &MachineDesc, x: i64, y: i64, gx: i64, gy: i64) -> f64 {
    let app = apps::stencil(&apps::StencilParams { x, y, gx, gy, halo: 1, steps: 3 });
    let spec = MapperSpec::compile(mappers::mapple_source("stencil").unwrap(), desc).unwrap();
    let mapper = MappleMapper::new(spec);
    let out = apps::run_app(&app, &mapper, desc).unwrap();
    assert!(out.sim.oom.is_none());
    out.sim.makespan
}

fn main() {
    let aspects = [1i64, 2, 4, 8, 16, 32];
    let areas = [1e6f64, 1e7, 1e8, 2e8, 4e8];
    let gpu_counts = [4usize, 8, 16, 32, 64, 128];
    println!(
        "Figures 14-17: decompose vs Algorithm 1 over {} configurations\n",
        aspects.len() * areas.len() * gpu_counts.len()
    );

    let mut configs = Vec::new();
    for &aspect in &aspects {
        for &area_per_node in &areas {
            for &gpus in &gpu_counts {
                configs.push(Config { aspect, area_per_node, gpus });
            }
        }
    }

    let mut improvements: Vec<f64> = Vec::new();
    let mut by_aspect: Vec<(i64, Vec<f64>)> = aspects.iter().map(|&a| (a, vec![])).collect();
    let mut by_area: Vec<(f64, Vec<f64>)> = areas.iter().map(|&a| (a, vec![])).collect();
    let mut by_gpus: Vec<(usize, Vec<f64>)> = gpu_counts.iter().map(|&g| (g, vec![])).collect();
    let mut rows = Vec::new();

    for cfg in &configs {
        let nodes = (cfg.gpus / 4).max(1);
        let desc = MachineDesc::paper_testbed(nodes);
        let total = cfg.gpus as u64;
        // iteration space with the requested aspect ratio and area:
        // x*y = area_per_node * nodes, y = aspect * x
        let area_total = cfg.area_per_node * nodes as f64;
        let x_f = (area_total / cfg.aspect as f64).sqrt();
        // round so every candidate grid divides the space cleanly: use a
        // multiple of 2·gpus in each dimension
        let m = 2 * cfg.gpus as i64;
        let x = round_to(x_f, m);
        let y = round_to(x_f * cfg.aspect as f64, m);

        let g = greedy_grid(total, 2);
        let d = decompose(total, &[x as u64, y as u64]);
        let (t_greedy, t_dec) = (
            run_stencil(&desc, x, y, g[0] as i64, g[1] as i64),
            run_stencil(&desc, x, y, d.factors[0] as i64, d.factors[1] as i64),
        );
        let ratio = t_greedy / t_dec; // >1 means decompose wins
        improvements.push(ratio);
        by_aspect.iter_mut().find(|(a, _)| *a == cfg.aspect).unwrap().1.push(ratio);
        by_area
            .iter_mut()
            .find(|(a, _)| *a == cfg.area_per_node)
            .unwrap()
            .1
            .push(ratio);
        by_gpus.iter_mut().find(|(gp, _)| *gp == cfg.gpus).unwrap().1.push(ratio);
        rows.push(Json::obj(vec![
            ("aspect", Json::Num(cfg.aspect as f64)),
            ("area_per_node", Json::Num(cfg.area_per_node)),
            ("gpus", Json::Num(cfg.gpus as f64)),
            ("greedy_s", Json::Num(t_greedy)),
            ("decompose_s", Json::Num(t_dec)),
            ("improvement", Json::Num(ratio)),
        ]));
    }

    // --- Fig 14: distribution of improvement percentage -------------------
    let pcts: Vec<f64> = improvements.iter().map(|r| (r - 1.0) * 100.0).collect();
    println!("Fig 14 — improvement distribution over {} configs:", pcts.len());
    let (edges, counts) = histogram(&pcts, 0.0, fmax(&pcts).max(1.0), 10);
    for (i, c) in counts.iter().enumerate() {
        println!(
            "  {:>6.1}%..{:>6.1}%  {:>3}  {}",
            edges[i],
            edges[i + 1],
            c,
            "#".repeat(*c)
        );
    }
    println!(
        "  min {:.1}%  max {:.1}%  geomean {:.1}%   (paper: 0%–83%, geomean 16%)\n",
        fmin(&pcts),
        fmax(&pcts),
        (geomean(&improvements) - 1.0) * 100.0
    );

    // --- Fig 15: vs aspect ratio ------------------------------------------
    let mut t = Table::new(["aspect ratio", "geomean improvement"]);
    for (a, v) in &by_aspect {
        t.row([format!("1:{a}"), format!("{:.1}%", (geomean(v) - 1.0) * 100.0)]);
    }
    println!("Fig 15 — improvement vs aspect ratio (paper: rises 7% → 27%):");
    print!("{}", t.render());

    // --- Fig 16: vs area per node ------------------------------------------
    let mut t = Table::new(["area / node", "geomean improvement"]);
    for (a, v) in &by_area {
        t.row([format!("{a:.0e}"), format!("{:.1}%", (geomean(v) - 1.0) * 100.0)]);
    }
    println!("\nFig 16 — improvement vs area per node (paper: falls 32% → 5%):");
    print!("{}", t.render());

    // --- Fig 17: vs machine size --------------------------------------------
    let mut t = Table::new(["GPUs", "geomean improvement"]);
    for (g, v) in &by_gpus {
        t.row([format!("{g}"), format!("{:.1}%", (geomean(v) - 1.0) * 100.0)]);
    }
    println!("\nFig 17 — improvement vs machine size (paper: peak at 16 GPUs / 4 nodes):");
    print!("{}", t.render());

    // shape assertions (who wins, where it helps most)
    let first_aspect = geomean(&by_aspect.first().unwrap().1);
    let last_aspect = geomean(&by_aspect.last().unwrap().1);
    assert!(
        last_aspect > first_aspect,
        "improvement must grow with aspect ratio: 1:1 {first_aspect} vs 1:32 {last_aspect}"
    );
    let small_area = geomean(&by_area.first().unwrap().1);
    let big_area = geomean(&by_area.last().unwrap().1);
    assert!(
        small_area > big_area,
        "improvement must shrink with area/node: {small_area} vs {big_area}"
    );
    let losses = improvements.iter().filter(|&&r| r < 0.97).count();
    assert!(losses < configs.len() / 10, "decompose lost in {losses}/{} configs", configs.len());

    write_report("fig14_decompose", &Json::obj(vec![("rows", Json::Arr(rows))]));
}
