//! Table 2 — performance tuning with Mapple: tuned Mapple mappers vs the
//! expert baselines across all nine applications (paper: speedups 1.02×
//! to 1.34×; scientific apps gain from memory placement, matmul apps
//! from mapping/placement of operand tiles).
//!
//! Run: `cargo bench --bench table2_tuning`

use mapple::bench::{build_bench_app, mapper_for, run, write_report, Flavor, APP_ORDER};
use mapple::machine::topology::MachineDesc;
use mapple::util::json::Json;
use mapple::util::table::Table;

fn main() {
    let desc = MachineDesc::paper_testbed(2); // 2 nodes × 4 GPUs
    println!(
        "Table 2: tuned Mapple mapper vs expert baseline ({} nodes x {} GPUs)\n",
        desc.nodes, desc.gpus_per_node
    );
    let mut t = Table::new([
        "#",
        "Application",
        "expert makespan",
        "tuned makespan",
        "Mapple tuned speedup",
    ]);
    let mut speedups = Vec::new();
    let mut rows = Vec::new();
    for (i, app_name) in APP_ORDER.iter().enumerate() {
        let app = build_bench_app(app_name, &desc);
        let expert = mapper_for(&Flavor::Expert, app_name, &desc);
        let tuned = mapper_for(&Flavor::Tuned, app_name, &desc);
        let base = run(&app, expert.as_ref(), &desc).unwrap();
        let opt = run(&app, tuned.as_ref(), &desc).unwrap();
        assert!(base.oom.is_none() && opt.oom.is_none(), "{app_name} OOM in Table 2 config");
        let speedup = base.makespan / opt.makespan;
        speedups.push(speedup);
        t.row([
            format!("{}", i + 1),
            app_name.to_string(),
            format!("{:.3} ms", base.makespan * 1e3),
            format!("{:.3} ms", opt.makespan * 1e3),
            format!("{speedup:.2}x"),
        ]);
        rows.push(Json::obj(vec![
            ("app", Json::Str(app_name.to_string())),
            ("expert_s", Json::Num(base.makespan)),
            ("tuned_s", Json::Num(opt.makespan)),
            ("speedup", Json::Num(speedup)),
        ]));
    }
    print!("{}", t.render());
    let max = speedups.iter().cloned().fold(0.0f64, f64::max);
    println!(
        "\nmax speedup {max:.2}x (paper: up to 1.34x); tuned never loses: min {:.2}x",
        speedups.iter().cloned().fold(f64::INFINITY, f64::min)
    );
    write_report("table2_tuning", &Json::obj(vec![("rows", Json::Arr(rows))]));
    assert!(
        speedups.iter().all(|&s| s > 0.95),
        "a tuned mapper regressed badly: {speedups:?}"
    );
}
