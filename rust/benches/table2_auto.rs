//! Table 2 (auto) — the simulator-guided autotuner vs the baseline and
//! hand-tuned Mapple mappers across all nine applications.
//!
//! Acceptance (ISSUE 4): the autotuned mapper is ≥ 1.0x vs the baseline
//! Mapple mapper on every app (guaranteed by construction — the search
//! is seeded with the baseline genome and only strictly better
//! candidates replace it) and matches or beats the hand-tuned spec on at
//! least 5 of 9 apps.
//!
//! Run: `cargo bench --bench table2_auto`

use mapple::bench::{build_bench_app, mapper_for, run, write_report, Flavor, APP_ORDER};
use mapple::machine::topology::MachineDesc;
use mapple::mapper::MappleMapper;
use mapple::tune::{tune, TuneConfig};
use mapple::util::json::Json;
use mapple::util::table::Table;

fn main() {
    let desc = MachineDesc::paper_testbed(2); // 2 nodes × 4 GPUs
    println!(
        "Table 2 (auto): autotuned vs baseline vs hand-tuned Mapple mappers \
         ({} nodes x {} GPUs)\n",
        desc.nodes, desc.gpus_per_node
    );
    let mut t = Table::new([
        "#",
        "Application",
        "mapple",
        "hand-tuned",
        "auto",
        "auto/mapple",
        "auto vs tuned",
        "evals",
    ]);
    let mut rows = Vec::new();
    let mut vs_mapple = Vec::new();
    let mut matches_tuned = 0usize;
    for (i, app_name) in APP_ORDER.iter().enumerate() {
        let app = build_bench_app(app_name, &desc);
        let base = run(&app, mapper_for(&Flavor::Mapple, app_name, &desc).as_ref(), &desc)
            .unwrap_or_else(|e| panic!("{app_name} mapple: {e}"));
        let tuned = run(&app, mapper_for(&Flavor::Tuned, app_name, &desc).as_ref(), &desc)
            .unwrap_or_else(|e| panic!("{app_name} tuned: {e}"));
        assert!(base.oom.is_none() && tuned.oom.is_none(), "{app_name}: reference OOM");

        let result = tune(&TuneConfig::quick(app_name, &desc))
            .unwrap_or_else(|e| panic!("{app_name} tune: {e}"));
        let auto_mapper = MappleMapper::new(result.best.build(&desc).unwrap());
        let auto = run(&app, &auto_mapper, &desc)
            .unwrap_or_else(|e| panic!("{app_name} auto: {e}"));
        assert!(auto.oom.is_none(), "{app_name}: autotuned mapper OOMs");

        let speedup = base.makespan / auto.makespan;
        let vs_tuned = tuned.makespan / auto.makespan;
        let matched = auto.makespan <= tuned.makespan * 1.001;
        vs_mapple.push(speedup);
        matches_tuned += usize::from(matched);
        t.row([
            format!("{}", i + 1),
            app_name.to_string(),
            format!("{:.3} ms", base.makespan * 1e3),
            format!("{:.3} ms", tuned.makespan * 1e3),
            format!("{:.3} ms", auto.makespan * 1e3),
            format!("{speedup:.2}x"),
            format!("{vs_tuned:.2}x{}", if matched { " ✓" } else { "" }),
            format!("{}", result.evaluated),
        ]);
        rows.push(Json::obj(vec![
            ("app", Json::Str(app_name.to_string())),
            ("mapple_s", Json::Num(base.makespan)),
            ("tuned_s", Json::Num(tuned.makespan)),
            ("auto_s", Json::Num(auto.makespan)),
            ("speedup_vs_mapple", Json::Num(speedup)),
            ("speedup_vs_tuned", Json::Num(vs_tuned)),
            ("matches_tuned", Json::Bool(matched)),
            ("edits", Json::Num(result.best.edits() as f64)),
            ("evaluated", Json::Num(result.evaluated as f64)),
        ]));
    }
    print!("{}", t.render());
    println!(
        "\nauto ≥ 1.0x vs baseline on all apps: min {:.3}x; matches/beats hand-tuned on {}/9",
        vs_mapple.iter().cloned().fold(f64::INFINITY, f64::min),
        matches_tuned
    );
    write_report("table2_auto", &Json::obj(vec![("rows", Json::Arr(rows))]));
    assert!(
        vs_mapple.iter().all(|&s| s >= 0.999),
        "autotuner must never lose to the baseline mapper: {vs_mapple:?}"
    );
    assert!(
        matches_tuned >= 5,
        "autotuner must match/beat the hand-tuned mapper on ≥5 of 9 apps, got {matches_tuned}"
    );
}
