//! Ablation (§7.2 generalizations): how the decompose objective changes
//! the chosen grid and the resulting communication volume for
//! (a) anisotropic halos (uneven widths per dimension) and
//! (b) transpose (all-to-all) traffic along one dimension —
//! versus using the plain isotropic objective or Algorithm 1.
//!
//! Run: `cargo bench --bench ablation_objectives`

use mapple::decompose::{decompose_with, greedy_grid, Objective};
use mapple::util::table::Table;

/// Analytic halo volume for factor grid d, extents l, halo widths h:
/// V = (Σ h_n d_n / l_n) · Π l_m  (paper §7.2.1).
fn halo_volume(d: &[u64], l: &[u64], h: &[f64]) -> f64 {
    let prod: f64 = l.iter().map(|&x| x as f64).product();
    h.iter()
        .zip(d.iter().zip(l))
        .map(|(&hn, (&dn, &ln))| hn * dn as f64 / ln as f64)
        .sum::<f64>()
        * prod
}

/// Transpose volume along dims marked in t (paper §7.2.2).
fn transpose_volume(d: &[u64], l: &[u64], t: &[bool]) -> f64 {
    let prod: f64 = l.iter().map(|&x| x as f64).product();
    t.iter()
        .zip(d)
        .filter(|(&tt, _)| tt)
        .map(|(_, &dn)| (1.0 - 1.0 / dn as f64) * prod)
        .sum()
}

fn main() {
    println!("Ablation: decompose objectives (§7.2 generalizations)\n");

    // (a) anisotropic halos: wide halo in dim 0
    println!("-- anisotropic halo: l = (4096, 4096), 16 procs, h = (8, 1) --");
    let l = [4096u64, 4096];
    let h = vec![8.0f64, 1.0];
    let mut t = Table::new(["strategy", "grid", "halo volume (elems)", "vs best"]);
    let candidates = [
        ("greedy (Alg 1)", greedy_grid(16, 2)),
        ("isotropic decompose", decompose_with(16, &l, &Objective::Isotropic).factors),
        (
            "anisotropic decompose",
            decompose_with(16, &l, &Objective::AnisotropicHalo(h.clone())).factors,
        ),
    ];
    let best = candidates
        .iter()
        .map(|(_, d)| halo_volume(d, &l, &h))
        .fold(f64::INFINITY, f64::min);
    for (name, d) in &candidates {
        let v = halo_volume(d, &l, &h);
        t.row([
            name.to_string(),
            format!("{d:?}"),
            format!("{v:.0}"),
            format!("{:.2}x", v / best),
        ]);
    }
    print!("{}", t.render());
    let aniso = &candidates[2].1;
    let iso = &candidates[1].1;
    assert!(
        halo_volume(aniso, &l, &h) <= halo_volume(iso, &l, &h),
        "anisotropic objective must not lose on anisotropic workloads"
    );

    // (b) transpose along dim 0 (e.g. FFT pencil decomposition)
    println!("\n-- halo + transpose along dim 0: l = (2048, 2048), 64 procs --");
    let l2 = [2048u64, 2048];
    let tdims = vec![true, false];
    let obj = Objective::WithTranspose { halo: vec![1.0, 1.0], transpose_dims: tdims.clone() };
    let mut t = Table::new(["strategy", "grid", "halo+a2a volume", "vs best"]);
    let cands = [
        ("greedy (Alg 1)", greedy_grid(64, 2)),
        ("isotropic decompose", decompose_with(64, &l2, &Objective::Isotropic).factors),
        ("transpose-aware decompose", decompose_with(64, &l2, &obj).factors),
    ];
    let vol = |d: &[u64]| halo_volume(d, &l2, &[1.0, 1.0]) + transpose_volume(d, &l2, &tdims);
    let best = cands.iter().map(|(_, d)| vol(d)).fold(f64::INFINITY, f64::min);
    for (name, d) in &cands {
        let v = vol(d);
        t.row([
            name.to_string(),
            format!("{d:?}"),
            format!("{v:.0}"),
            format!("{:.2}x", v / best),
        ]);
    }
    print!("{}", t.render());
    let ta = &cands[2].1;
    assert!((vol(ta) - best).abs() < 1e-6, "transpose-aware must be optimal");
    println!("\nSame search (§4.3), different objective — only the objective changes (§7.2).");
}
