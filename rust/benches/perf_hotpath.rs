//! Hot-path microbenchmarks (EXPERIMENTS.md §Perf): the L3 paths that run
//! per task launch —
//!   1. launch-domain mapping, all three tiers: per-point tree-walking
//!      interpreter vs the batched MappingPlan VM (prelude hoisting +
//!      register bytecode) vs the closure-compiled tier (`mapple::compile`,
//!      the default behind `eval_domain`),
//!   2. per-point lookup through the MappleMapper's cached tables,
//!   3. decompose solve: cold search vs memo hit,
//!   4. end-to-end map+simulate for a full Cannon program.
//!
//! Two gates on the 1024-point launch: the VM must be ≥2x the tree
//! walker, and the compiled tier must be ≥1.5x the VM on top of that.
//! CI runs this on noisy shared runners, so each gate takes the **best
//! speedup over a few trials**: scheduler interference can only slow a
//! trial down, so the best trial is the closest observation of the true
//! ratio and a single descheduled sample cannot fail the job spuriously.
//!
//! Run: `cargo bench --bench perf_hotpath`

use mapple::apps::{self, mappers};
use mapple::bench::{mapper_for, run, Flavor};
use mapple::decompose::{decompose_with, Objective};
use mapple::machine::point::{Rect, Tuple};
use mapple::machine::topology::MachineDesc;
use mapple::mapper::api::{Mapper, TaskCtx};
use mapple::mapper::MappleMapper;
use mapple::mapple::MapperSpec;
use mapple::util::bench::Bencher;

fn main() {
    let desc = MachineDesc::paper_testbed(4);

    println!("== 1. launch-domain mapping: tree-walker vs VM vs compiled closures ==");
    let src = mappers::mapple_source("cannon").unwrap();
    let spec = MapperSpec::compile(src, &desc).unwrap();
    assert!(
        spec.plan.supports("hierarchical_block2D"),
        "cannon mapper must compile to bytecode"
    );
    assert!(
        spec.plan.compiled_for("hierarchical_block2D"),
        "cannon mapper must reach the closure-compiled tier"
    );
    let ispace = Tuple::from([32, 32]); // 1024-point launch
    let dom = Rect::from_extent(&ispace);
    let points: Vec<Tuple> = dom.points().collect();
    let b1 = Bencher { warmup_iters: 2, samples: 15, iters_per_sample: 2 };
    // Gate on the best of a few trials: CI-runner noise only ever slows a
    // trial down, so the max over trials is the robust estimate.
    const TRIALS: usize = 3;
    let mut best_vm_speedup = 0.0f64;
    let mut best_compiled_speedup = 0.0f64;
    let mut m_interp_median = f64::NAN;
    for trial in 0..TRIALS {
        let m_interp = b1.run("tree-walker, 1024 points (per-point)", || {
            let mut last = None;
            for p in &points {
                last = Some(spec.map_point("mm_step_0", p, &ispace).unwrap());
            }
            last
        });
        let m_vm = b1.run("MappingPlan VM, 1024 points (batched)", || {
            spec.plan.eval_domain_vm("hierarchical_block2D", &dom).unwrap()
        });
        let m_compiled = b1.run("compiled closures, 1024 points (batched)", || {
            spec.plan.eval_domain("hierarchical_block2D", &dom).unwrap()
        });
        if trial == 0 {
            println!("  {}", m_interp.summary());
            println!("  {}", m_vm.summary());
            println!("  {}", m_compiled.summary());
            m_interp_median = m_interp.median();
        }
        let vm_speedup = m_interp.median() / m_vm.median();
        let compiled_speedup = m_vm.median() / m_compiled.median();
        println!(
            "  trial {}: VM {vm_speedup:.1}x over tree-walker, \
             compiled {compiled_speedup:.1}x over VM",
            trial + 1
        );
        best_vm_speedup = best_vm_speedup.max(vm_speedup);
        best_compiled_speedup = best_compiled_speedup.max(compiled_speedup);
        if best_vm_speedup >= 2.0 && best_compiled_speedup >= 1.5 {
            break; // both gates already met; no need to burn more CI time
        }
    }
    println!(
        "  best VM speedup over tree-walker: {best_vm_speedup:.1}x  [{}]",
        if best_vm_speedup >= 2.0 { "PASS ≥2x" } else { "FAIL <2x" }
    );
    println!(
        "  best compiled speedup over VM: {best_compiled_speedup:.1}x  [{}]\n",
        if best_compiled_speedup >= 1.5 { "PASS ≥1.5x" } else { "FAIL <1.5x" }
    );
    assert!(
        best_vm_speedup >= 2.0,
        "MappingPlan VM must be ≥2x the per-point tree-walker in the best of \
         {TRIALS} trials (got {best_vm_speedup:.2}x)"
    );
    assert!(
        best_compiled_speedup >= 1.5,
        "compiled closures must be ≥1.5x the bytecode VM in the best of \
         {TRIALS} trials (got {best_compiled_speedup:.2}x)"
    );

    println!("== 2. per-point lookup through the cached placement table ==");
    let b = Bencher { warmup_iters: 10, samples: 20, iters_per_sample: 100 };
    let mapper = MappleMapper::new(MapperSpec::compile(src, &desc).unwrap());
    let ctx = TaskCtx {
        task_name: "mm_step_0",
        launch_domain: &dom,
        num_nodes: desc.nodes,
        procs_per_node: desc.gpus_per_node,
    };
    let mut j = 0i64;
    let m_cached = b.run("MappleMapper map_task (cached plan)", || {
        j = (j + 1) % 1024;
        mapper.map_task(&ctx, &Tuple::from([j / 32, j % 32]), &ispace).unwrap()
    });
    println!("  {}", m_cached.summary());
    println!(
        "  cached point lookup vs tree-walker point: {:.1}x\n",
        (m_interp_median / 1024.0) / m_cached.median()
    );

    println!("== 3. decompose solve: cold vs memoized ==");
    let mut k = 0u64;
    let cold = b.run("decompose cold (fresh extents)", || {
        k += 1;
        decompose_with(96, &[1000 + k, 2000 + k], &Objective::Isotropic)
    });
    println!("  {}", cold.summary());
    let hot = b.run("decompose memo hit", || {
        decompose_with(96, &[1000, 2000], &Objective::Isotropic)
    });
    println!("  {}", hot.summary());
    println!("  memo speedup: {:.1}x\n", cold.median() / hot.median());

    println!("== 4. end-to-end map+simulate (cannon, 16 GPUs, N=4096) ==");
    let b2 = Bencher { warmup_iters: 1, samples: 5, iters_per_sample: 1 };
    let app = apps::cannon(4096, 16);
    let m = b2.run("pipeline+sim cannon", || {
        let mapper = mapper_for(&Flavor::Mapple, "cannon", &desc);
        run(&app, mapper.as_ref(), &desc).unwrap()
    });
    println!("  {}", m.summary());
    let points: i64 = app.launches.iter().map(|l| l.num_points()).sum();
    println!(
        "  {:.1} µs per point task end-to-end ({points} tasks)",
        m.median() * 1e6 / points as f64
    );
}
