//! Hot-path microbenchmarks (EXPERIMENTS.md §Perf): the L3 paths that run
//! per task launch —
//!   1. mapping-point evaluation: raw interpreter vs the MappleMapper's
//!      per-(task, ispace) table cache (the §Perf optimization),
//!   2. decompose solve: cold search vs memo hit,
//!   3. end-to-end map+simulate for a full Cannon program.
//!
//! Run: `cargo bench --bench perf_hotpath`

use mapple::apps::{self, mappers};
use mapple::bench::{mapper_for, run, Flavor};
use mapple::decompose::{decompose_with, Objective};
use mapple::machine::point::{Rect, Tuple};
use mapple::machine::topology::MachineDesc;
use mapple::mapper::api::{Mapper, TaskCtx};
use mapple::mapper::MappleMapper;
use mapple::mapple::MapperSpec;
use mapple::util::bench::Bencher;

fn main() {
    let desc = MachineDesc::paper_testbed(4);
    let b = Bencher { warmup_iters: 10, samples: 20, iters_per_sample: 100 };

    println!("== 1. per-point mapping: interpreter vs table cache ==");
    let src = mappers::mapple_source("cannon").unwrap();
    let spec = MapperSpec::compile(src, &desc).unwrap();
    let ispace = Tuple::from([8, 8]);
    let dom = Rect::from_extent(&ispace);
    let mut i = 0i64;
    let m_interp = b.run("interpreter map_point (uncached)", || {
        i = (i + 1) % 64;
        spec.map_point("mm_step_0", &Tuple::from([i / 8, i % 8]), &ispace).unwrap()
    });
    println!("  {}", m_interp.summary());

    let mapper = MappleMapper::new(MapperSpec::compile(src, &desc).unwrap());
    let ctx = TaskCtx {
        task_name: "mm_step_0",
        launch_domain: &dom,
        num_nodes: desc.nodes,
        procs_per_node: desc.gpus_per_node,
    };
    let mut j = 0i64;
    let m_cached = b.run("MappleMapper map_task (cached)", || {
        j = (j + 1) % 64;
        mapper.map_task(&ctx, &Tuple::from([j / 8, j % 8]), &ispace).unwrap()
    });
    println!("  {}", m_cached.summary());
    println!(
        "  cache speedup: {:.1}x\n",
        m_interp.median() / m_cached.median()
    );

    println!("== 2. decompose solve: cold vs memoized ==");
    let mut k = 0u64;
    let cold = b.run("decompose cold (fresh extents)", || {
        k += 1;
        decompose_with(96, &[1000 + k, 2000 + k], &Objective::Isotropic)
    });
    println!("  {}", cold.summary());
    let hot = b.run("decompose memo hit", || {
        decompose_with(96, &[1000, 2000], &Objective::Isotropic)
    });
    println!("  {}", hot.summary());
    println!("  memo speedup: {:.1}x\n", cold.median() / hot.median());

    println!("== 3. end-to-end map+simulate (cannon, 16 GPUs, N=4096) ==");
    let b2 = Bencher { warmup_iters: 1, samples: 5, iters_per_sample: 1 };
    let app = apps::cannon(4096, 16);
    let m = b2.run("pipeline+sim cannon", || {
        let mapper = mapper_for(&Flavor::Mapple, "cannon", &desc);
        run(&app, mapper.as_ref(), &desc).unwrap()
    });
    println!("  {}", m.summary());
    let points: i64 = app.launches.iter().map(|l| l.num_points()).sum();
    println!(
        "  {:.1} µs per point task end-to-end ({points} tasks)",
        m.median() * 1e6 / points as f64
    );
}
