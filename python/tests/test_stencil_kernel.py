"""L1 correctness: Pallas 5-point stencil vs the pure-jnp oracle."""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels.ref import stencil5_ref
from compile.kernels.stencil5 import stencil5, vmem_bytes


def rand(shape, seed):
    key = jax.random.PRNGKey(seed)
    return jax.random.normal(key, shape, dtype=jnp.float32)


def make_inputs(x, y, seed):
    return (
        rand((x, y), seed),
        rand((1, y), seed + 1),
        rand((1, y), seed + 2),
        rand((x, 1), seed + 3),
        rand((x, 1), seed + 4),
    )


@pytest.mark.parametrize("x,y", [(4, 4), (8, 16), (32, 32), (3, 7)])
def test_matches_ref(x, y):
    args = make_inputs(x, y, 0)
    np.testing.assert_allclose(stencil5(*args), stencil5_ref(*args), rtol=1e-5, atol=1e-6)


@hypothesis.settings(max_examples=25, deadline=None)
@hypothesis.given(
    x=st.integers(2, 48), y=st.integers(2, 48), seed=st.integers(0, 2**16)
)
def test_matches_ref_hypothesis(x, y, seed):
    args = make_inputs(x, y, seed)
    np.testing.assert_allclose(stencil5(*args), stencil5_ref(*args), rtol=1e-5, atol=1e-6)


def test_uniform_field_is_fixed_point():
    # weights sum to 1.0 → a constant field stays constant
    x, y = 8, 8
    g = jnp.ones((x, y), jnp.float32) * 3.5
    n = jnp.ones((1, y), jnp.float32) * 3.5
    s = jnp.ones((1, y), jnp.float32) * 3.5
    w = jnp.ones((x, 1), jnp.float32) * 3.5
    e = jnp.ones((x, 1), jnp.float32) * 3.5
    out = stencil5(g, n, s, w, e)
    np.testing.assert_allclose(out, g, rtol=1e-6)


def test_halo_shape_validation():
    with pytest.raises(AssertionError):
        stencil5(rand((4, 4), 0), rand((2, 4), 1), rand((1, 4), 2), rand((4, 1), 3), rand((4, 1), 4))


def test_vmem_estimate_reasonable():
    assert vmem_bytes(64, 128) < 16 * 2**20
