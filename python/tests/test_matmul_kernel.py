"""L1 correctness: Pallas tile GEMM vs the pure-jnp oracle, swept over
shapes/blocks with hypothesis."""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels.matmul_tile import matmul_tile, pick_block, vmem_bytes
from compile.kernels.ref import matmul_ref


def rand(shape, seed):
    key = jax.random.PRNGKey(seed)
    return jax.random.normal(key, shape, dtype=jnp.float32)


@pytest.mark.parametrize("m,k,n", [(8, 8, 8), (16, 32, 8), (64, 64, 64), (128, 64, 32)])
def test_matches_ref_basic(m, k, n):
    a, b = rand((m, k), 0), rand((k, n), 1)
    got = matmul_tile(a, b)
    want = matmul_ref(a, b)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


dims = st.sampled_from([4, 8, 12, 16, 24, 32, 48, 64])


@hypothesis.settings(max_examples=25, deadline=None)
@hypothesis.given(m=dims, k=dims, n=dims, seed=st.integers(0, 2**16))
def test_matches_ref_hypothesis(m, k, n, seed):
    a, b = rand((m, k), seed), rand((k, n), seed + 1)
    got = matmul_tile(a, b)
    want = matmul_ref(a, b)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@hypothesis.settings(max_examples=15, deadline=None)
@hypothesis.given(
    m=st.sampled_from([16, 32, 64]),
    bm=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 100),
)
def test_explicit_blocks(m, bm, seed):
    a, b = rand((m, m), seed), rand((m, m), seed + 7)
    got = matmul_tile(a, b, bm=bm, bk=bm, bn=bm)
    np.testing.assert_allclose(got, matmul_ref(a, b), rtol=1e-4, atol=1e-4)


def test_pick_block():
    assert pick_block(64) == 64
    assert pick_block(128) == 64
    assert pick_block(48) == 48
    assert pick_block(7) == 7
    assert pick_block(7, preferred=4) == 1
    for d in range(1, 130):
        b = pick_block(d)
        assert d % b == 0 and b <= 64


def test_vmem_budget_within_tpu_limits():
    # default 64-blocks: 4*(64*64*3 + 64*64) = 64 KiB << 16 MiB VMEM
    assert vmem_bytes(64, 64, 64) <= 16 * 2**20
    assert vmem_bytes(128, 128, 128) <= 16 * 2**20


def test_rejects_mismatched_inner_dims():
    with pytest.raises(AssertionError):
        matmul_tile(rand((8, 16), 0), rand((8, 8), 1))
