"""L2 model + AOT lowering checks: shapes, numerics of gemm_accumulate,
and HLO-text emission round-trip (parse side is covered by the Rust
integration test)."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels.ref import matmul_ref


def rand(shape, seed):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, dtype=jnp.float32)


def test_gemm_accumulate_numerics():
    a, b, c = rand((32, 32), 0), rand((32, 32), 1), rand((32, 32), 2)
    (got,) = model.gemm_accumulate(a, b, c)
    np.testing.assert_allclose(got, c + matmul_ref(a, b), rtol=1e-4, atol=1e-4)


def test_stencil_step_shape():
    args = model.example_args_stencil(16, 24)
    concrete = [jnp.zeros(s.shape, s.dtype) for s in args]
    (out,) = model.stencil_step(*concrete)
    assert out.shape == (16, 24)


def test_gemm_flops():
    assert model.gemm_flops(64, 64, 64) == 2 * 64**3 + 64 * 64


@pytest.mark.parametrize("ts", [16, 64])
def test_hlo_text_emission(ts):
    with tempfile.TemporaryDirectory() as d:
        path = aot.emit(d, f"matmul_tile_{ts}", model.gemm_accumulate,
                        model.example_args_gemm(ts))
        assert os.path.getsize(path) > 100
        text = open(path).read()
        assert "HloModule" in text, "must be HLO text, not proto bytes"
        # three f32 parameters of the right shape
        assert text.count(f"f32[{ts},{ts}]") >= 3


def test_emitted_hlo_has_entry():
    with tempfile.TemporaryDirectory() as d:
        path = aot.emit(d, "stencil", model.stencil_step,
                        model.example_args_stencil(8, 8))
        text = open(path).read()
        assert "ENTRY" in text
