"""Pure-jnp oracles for the Pallas kernels (pytest compares against these)."""

from __future__ import annotations

import jax.numpy as jnp


def matmul_ref(a, b):
    """Reference GEMM in f32."""
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32),
                   preferred_element_type=jnp.float32)


def stencil5_ref(grid, north, south, west, east, w_center=0.6, w_nbr=0.1):
    """Reference 5-point stencil step with explicit halo rows/cols.

    grid:  (X, Y) interior values
    north: (1, Y) halo row above, south: (1, Y) below
    west:  (X, 1) halo col left,  east:  (X, 1) right
    """
    up = jnp.concatenate([north, grid[:-1, :]], axis=0)
    down = jnp.concatenate([grid[1:, :], south], axis=0)
    left = jnp.concatenate([west, grid[:, :-1]], axis=1)
    right = jnp.concatenate([grid[:, 1:], east], axis=1)
    return w_center * grid + w_nbr * (up + down + left + right)
