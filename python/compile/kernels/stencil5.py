"""L1 Pallas kernel: 5-point stencil update over a tile with explicit
halo rows/columns (the leaf task of the Stencil benchmark).

The tile plus four halo strips arrive as separate refs — mirroring the
distributed layout, where halos are exchanged between processors and the
interior tile never moves. Block layout keeps the whole tile in VMEM
(tiles are sized by the mapper so tile_bytes << 16 MiB VMEM); on TPU the
row-shifted adds vectorize onto the VPU's 8x128 lanes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

W_CENTER = 0.6
W_NBR = 0.1


def _stencil_kernel(grid_ref, north_ref, south_ref, west_ref, east_ref, o_ref):
    grid = grid_ref[...]
    north = north_ref[...]
    south = south_ref[...]
    west = west_ref[...]
    east = east_ref[...]
    up = jnp.concatenate([north, grid[:-1, :]], axis=0)
    down = jnp.concatenate([grid[1:, :], south], axis=0)
    left = jnp.concatenate([west, grid[:, :-1]], axis=1)
    right = jnp.concatenate([grid[:, 1:], east], axis=1)
    o_ref[...] = W_CENTER * grid + W_NBR * (up + down + left + right)


@jax.jit
def stencil5(grid, north, south, west, east):
    """One 5-point stencil step on a tile with halo strips."""
    x, y = grid.shape
    assert north.shape == (1, y) and south.shape == (1, y), (north.shape, south.shape)
    assert west.shape == (x, 1) and east.shape == (x, 1), (west.shape, east.shape)
    return pl.pallas_call(
        _stencil_kernel,
        out_shape=jax.ShapeDtypeStruct((x, y), jnp.float32),
        interpret=True,  # CPU-PJRT execution; Mosaic lowering is TPU-only
    )(grid, north, south, west, east)


def vmem_bytes(x: int, y: int) -> int:
    """VMEM footprint estimate for DESIGN.md's roofline notes."""
    tile = x * y
    halos = 2 * y + 2 * x
    return 4 * (2 * tile + halos)  # in + out + strips, f32


functools  # referenced for parity with matmul_tile's interface
