"""L1 Pallas kernel: blocked tile GEMM (C = A @ B) for the leaf tasks of
the distributed matmul algorithms.

TPU-shaped even though we execute interpret=True on CPU: the grid tiles
the output into MXU-friendly (BM, BN) blocks, the K dimension is walked
by the innermost grid axis with a VMEM accumulator, and block shapes are
multiples of the 128x128 systolic array where the problem allows.

VMEM budget per program instance (f32):
    BM*BK + BK*BN + BM*BN floats = (64*64)*3*4 B = 48 KiB  << 16 MiB VMEM
so double-buffering headroom is ample; on real TPU the pipeline overlaps
the HBM->VMEM streams of A and B with MXU work.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, k_steps: int):
    """One (i, j, k) grid step: acc += A[i,k] @ B[k,j]; flush on last k."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == k_steps - 1)
    def _flush():
        o_ref[...] = acc_ref[...]


def pick_block(dim: int, preferred: int = 64) -> int:
    """Largest block <= preferred that divides dim (halving until it does)."""
    b = min(preferred, dim)
    while dim % b != 0:
        b //= 2
    return max(b, 1)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn"))
def matmul_tile(a, b, *, bm: int = 0, bk: int = 0, bn: int = 0):
    """Blocked Pallas GEMM. Block sizes default to the largest
    power-of-two divisors (<= 64) of each dimension."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims mismatch: {a.shape} @ {b.shape}"
    bm = bm or pick_block(m)
    bk = bk or pick_block(k)
    bn = bn or pick_block(n)
    k_steps = k // bk
    grid = (m // bm, n // bn, k_steps)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, k_steps=k_steps),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=True,  # CPU-PJRT execution; Mosaic lowering is TPU-only
    )(a, b)


def vmem_bytes(bm: int, bk: int, bn: int) -> int:
    """Per-instance VMEM footprint estimate (A+B blocks, out, acc), f32.
    Recorded in DESIGN.md's roofline notes."""
    return 4 * (bm * bk + bk * bn + 2 * bm * bn)
