"""compile package"""
