"""L2: the JAX compute graph for leaf tasks, calling the L1 Pallas
kernels. These are the functions `aot.py` lowers to HLO text for the
Rust runtime; Python never runs at request time.

The distributed algorithms' leaf work:
  * `gemm_accumulate(a, b, c)` — one systolic/broadcast step of the
    matmul benchmarks: C += A @ B on local tiles (Pallas GEMM inside).
  * `stencil_step(grid, n, s, w, e)` — one halo-exchange stencil update.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.matmul_tile import matmul_tile
from .kernels.stencil5 import stencil5


@jax.jit
def gemm_accumulate(a, b, c):
    """One distributed-matmul step on local tiles: returns C + A @ B.

    The Pallas kernel computes the tile product; the accumulate stays in
    the surrounding jax function so XLA fuses the add into the same
    program (no extra HBM round-trip on real hardware).
    """
    return (c + matmul_tile(a, b),)


@jax.jit
def stencil_step(grid, north, south, west, east):
    """One 5-point stencil timestep on a tile with halo strips."""
    return (stencil5(grid, north, south, west, east),)


def gemm_flops(m: int, k: int, n: int) -> float:
    """Useful FLOPs of one gemm_accumulate call (for perf accounting)."""
    return 2.0 * m * k * n + m * n


def example_args_gemm(ts: int):
    """Example (a, b, c) shapes for a tile size."""
    spec = jax.ShapeDtypeStruct((ts, ts), jnp.float32)
    return spec, spec, spec


def example_args_stencil(x: int, y: int):
    f = jnp.float32
    return (
        jax.ShapeDtypeStruct((x, y), f),
        jax.ShapeDtypeStruct((1, y), f),
        jax.ShapeDtypeStruct((1, y), f),
        jax.ShapeDtypeStruct((x, 1), f),
        jax.ShapeDtypeStruct((x, 1), f),
    )
