"""AOT lowering: jax functions -> HLO *text* artifacts for the Rust
runtime (`rust/src/runtime/pjrt.rs`).

Interchange format is HLO text, NOT `.serialize()`: jax >= 0.5 emits
HloModuleProtos with 64-bit instruction ids which the published xla crate
(xla_extension 0.5.1) rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage: python -m compile.aot [--out-dir ../artifacts]
Emits:
  matmul_tile.hlo.txt            default 64x64 tile gemm_accumulate
  matmul_tile_<ts>.hlo.txt       per tile size in TILE_SIZES
  stencil5_<x>x<y>.hlo.txt       stencil steps for the e2e examples
"""

from __future__ import annotations

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from . import model

TILE_SIZES = (16, 32, 64, 128)
STENCIL_SHAPES = ((32, 32), (64, 128))
DEFAULT_TILE = 64


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True so the
    Rust side unwraps with to_tuple())."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def emit(out_dir: str, name: str, fn, example_args) -> str:
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    return path


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    emitted = []
    for ts in TILE_SIZES:
        emitted.append(
            emit(args.out_dir, f"matmul_tile_{ts}", model.gemm_accumulate,
                 model.example_args_gemm(ts))
        )
    # default-name artifact used when the app doesn't pick a tile size
    emitted.append(
        emit(args.out_dir, "matmul_tile", model.gemm_accumulate,
             model.example_args_gemm(DEFAULT_TILE))
    )
    for (x, y) in STENCIL_SHAPES:
        emitted.append(
            emit(args.out_dir, f"stencil5_{x}x{y}", model.stencil_step,
                 model.example_args_stencil(x, y))
        )
    for p in emitted:
        print(f"wrote {p} ({os.path.getsize(p)} bytes)")


if __name__ == "__main__":
    main()
